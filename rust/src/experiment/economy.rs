//! Replica-economy sweep (ISSUE 10 tentpole): popularity-driven
//! replication/eviction vs static placement.
//!
//! [`run_economy`] replays *identical* request traces on *identically
//! seeded* grids twice per scenario — once with the placement frozen at
//! its seed state (`economy: None`, the pre-ISSUE-10 behaviour) and
//! once with the [`crate::broker::Economy`] policy engine ticking
//! inside the open-loop kernel, replicating hot files through real
//! GridFTP store flows and evicting cold copies under per-site space
//! budgets. Because grid, workload and weather are bit-identical
//! across the two arms, any difference in hit-rate-at-nearest-replica,
//! mean/p95 time or completion rate is attributable to the economy
//! alone; its price is reported as `bytes_moved`.
//!
//! Three canonical demand shapes exercise the economy from different
//! directions:
//!
//! * **flash-crowd** — a Poisson background until one file abruptly
//!   absorbs most of the arrival stream ([`flash_crowd`]); the economy
//!   must detect the spike and fan the file out before the crowd
//!   drains.
//! * **diurnal-shift** — demand moves wholesale from one half of the
//!   catalog to the other mid-run ([`diurnal_shift`]); the economy must
//!   both replicate the newly hot set and reclaim space from the
//!   abandoned one.
//! * **cold-start** — the plain workload against a grid seeded with a
//!   *single* copy of every file; the economy grows the placement from
//!   nothing.
//!
//! The headline metric is **hit-rate-at-nearest-replica**: the
//! fraction of completed requests served from the site that minimizes
//! the *nominal* configured cost `latency + drdTime +
//! size / min(wan_bandwidth, disk_rate)` over **all** sites
//! ([`nearest_site`]) — i.e. how often the data was already where a
//! clairvoyant placer would have put it. Static placement can only hit
//! when the seed shuffle happened to land a copy there; the economy is
//! supposed to move the data. `bench_economy` records the sweep as
//! `BENCH_economy.json`.

use crate::broker::selectors::SelectorKind;
use crate::broker::EconomyOptions;
use crate::config::GridConfig;
use crate::simnet::{Request, Workload, WorkloadSpec};

use super::open_loop::{run_quality_open, OpenLoopOptions, OpenReport};

/// Shared knobs of one economy sweep.
#[derive(Debug, Clone)]
pub struct EconomySweepOptions {
    /// Selection policy both arms run under.
    pub kind: SelectorKind,
    /// Base open-loop configuration (`economy` is overwritten per arm).
    pub open: OpenLoopOptions,
    /// The policy-engine knobs of the economy arm.
    pub economy: EconomyOptions,
}

impl Default for EconomySweepOptions {
    fn default() -> Self {
        EconomySweepOptions {
            kind: SelectorKind::Forecast,
            open: OpenLoopOptions::open(),
            economy: EconomyOptions::default(),
        }
    }
}

/// One placement regime's outcome on one demand shape.
#[derive(Debug, Clone)]
pub struct EconomyArm {
    /// Mean transfer duration over completed requests (s).
    pub mean_time: f64,
    /// p95 transfer duration over completed requests (s).
    pub p95: f64,
    /// Finished requests / total requests.
    pub completion_rate: f64,
    /// Fraction of completed requests served from [`nearest_site`].
    pub hit_rate_nearest: f64,
    /// Background replication traffic the economy paid (0 when off).
    pub bytes_moved: f64,
    pub replicas_created: usize,
    pub evictions: usize,
    pub failed_pushes: usize,
    /// The full open-loop report, for drill-down.
    pub report: OpenReport,
}

/// One demand shape: static placement vs the economy on identical
/// inputs.
#[derive(Debug, Clone)]
pub struct EconomyPoint {
    pub label: String,
    pub static_placement: EconomyArm,
    pub economy: EconomyArm,
}

/// The whole sweep.
#[derive(Debug, Clone)]
pub struct EconomyReport {
    pub points: Vec<EconomyPoint>,
}

/// Topology index of the site a `size`-byte transfer would nominally
/// finish fastest from, ignoring placement entirely: the argmin over
/// *all* sites of the closed-form configured cost
/// `latency + drdTime + size / min(wan_bandwidth, disk_rate)`.
///
/// This is a property of the *configuration*, not of any run — which
/// is exactly why it can score placement: a request served from here
/// means the data was already at the best spot the grid offers.
pub fn nearest_site(cfg: &GridConfig, size: f64) -> usize {
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for (i, s) in cfg.sites.iter().enumerate() {
        let rate = s.wan_bandwidth.min(s.disk_rate).max(1.0);
        let cost = s.latency + s.drd_time_ms / 1e3 + size / rate;
        if cost < best_cost {
            best = i;
            best_cost = cost;
        }
    }
    best
}

/// Flash crowd: the base Poisson trace, except from a third of the way
/// in, 4 of every 5 requests redirect onto file 0. Arrival instants
/// (and thus kernel scheduling) are untouched — only demand moves.
pub fn flash_crowd(spec: &WorkloadSpec, seed: u64, n: usize) -> Vec<Request> {
    let mut reqs = Workload::new(spec.clone(), seed).take(n);
    let onset = n / 3;
    for (i, r) in reqs.iter_mut().enumerate() {
        if i >= onset && i % 5 != 0 {
            r.file = 0;
        }
    }
    reqs
}

/// Diurnal region shift: the first half of the trace draws from the
/// low half of the catalog, the second half from the high half — the
/// "follow the sun" pattern where yesterday's hot set goes cold all at
/// once.
pub fn diurnal_shift(spec: &WorkloadSpec, seed: u64, n: usize) -> Vec<Request> {
    let mut reqs = Workload::new(spec.clone(), seed).take(n);
    let lo = (spec.files / 2).max(1);
    let hi = spec.files.saturating_sub(lo).max(1);
    for (i, r) in reqs.iter_mut().enumerate() {
        if i < n / 2 {
            r.file %= lo;
        } else {
            r.file = (lo + r.file % hi).min(spec.files.saturating_sub(1));
        }
    }
    reqs
}

fn arm(report: OpenReport, requests: &[Request], nearest_by_file: &[usize]) -> EconomyArm {
    let total = requests.len();
    let finished = report.per_request.len();
    let mut hits = 0usize;
    for t in &report.per_request {
        if t.site == nearest_by_file[requests[t.request].file] {
            hits += 1;
        }
    }
    let stats = report.economy.unwrap_or_default();
    EconomyArm {
        mean_time: report.quality.mean_time,
        p95: report.quality.p95_time,
        completion_rate: if total == 0 { 0.0 } else { finished as f64 / total as f64 },
        hit_rate_nearest: if finished == 0 { 0.0 } else { hits as f64 / finished as f64 },
        bytes_moved: stats.bytes_moved,
        replicas_created: stats.replicas_created,
        evictions: stats.evictions,
        failed_pushes: stats.failed_pushes,
        report,
    }
}

/// One demand shape, both arms. The static arm runs with
/// `economy: None` — the parity anchor `it_economy` pins bit-identical
/// to a plain [`run_quality_open`]; the economy arm differs *only* in
/// [`OpenLoopOptions::economy`].
pub fn run_economy_point(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    replicas_per_file: usize,
    warm: usize,
    opts: &EconomySweepOptions,
    label: &str,
) -> EconomyPoint {
    let sizes = Workload::file_sizes(spec, cfg.seed, 80.0);
    let nearest: Vec<usize> = sizes.iter().map(|&b| nearest_site(cfg, b)).collect();
    let run = |economy: Option<EconomyOptions>| {
        let o = OpenLoopOptions { economy, ..opts.open.clone() };
        let r = run_quality_open(cfg, spec, requests, replicas_per_file, warm, opts.kind, &o, None);
        arm(r, requests, &nearest)
    };
    let static_placement = run(None);
    let economy = run(Some(opts.economy));
    EconomyPoint {
        label: label.to_string(),
        static_placement,
        economy,
    }
}

/// The canonical three-scenario sweep: flash crowd and diurnal shift
/// at `replicas_per_file`, cold-start at a single seed copy per file.
pub fn run_economy(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    n_requests: usize,
    replicas_per_file: usize,
    warm: usize,
    opts: &EconomySweepOptions,
) -> EconomyReport {
    let flash = flash_crowd(spec, cfg.seed, n_requests);
    let shift = diurnal_shift(spec, cfg.seed, n_requests);
    let cold = Workload::new(spec.clone(), cfg.seed).take(n_requests);
    let points = vec![
        run_economy_point(cfg, spec, &flash, replicas_per_file, warm, opts, "flash-crowd"),
        run_economy_point(cfg, spec, &shift, replicas_per_file, warm, opts, "diurnal-shift"),
        run_economy_point(cfg, spec, &cold, 1, warm, opts, "cold-start"),
    ];
    EconomyReport { points }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flash_crowd_redirects_the_tail_onto_file_zero() {
        let spec = WorkloadSpec { files: 8, ..Default::default() };
        let reqs = flash_crowd(&spec, 5, 30);
        assert_eq!(reqs.len(), 30);
        let tail_hot = reqs[10..].iter().filter(|r| r.file == 0).count();
        assert!(tail_hot >= 16, "the crowd must concentrate: {tail_hot}/20");
        // Arrival instants are the base trace's, untouched and sorted.
        for w in reqs.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn diurnal_shift_partitions_the_catalog_by_half() {
        let spec = WorkloadSpec { files: 8, ..Default::default() };
        let reqs = diurnal_shift(&spec, 5, 40);
        assert!(reqs[..20].iter().all(|r| r.file < 4), "first half draws low");
        assert!(reqs[20..].iter().all(|r| r.file >= 4), "second half draws high");
        assert!(reqs.iter().all(|r| r.file < 8));
    }

    #[test]
    fn nearest_site_prefers_the_configured_fast_site() {
        let mut cfg = GridConfig::generate(4, 17);
        for s in &mut cfg.sites {
            s.wan_bandwidth = 1e5;
            s.latency = 0.5;
        }
        cfg.sites[2].wan_bandwidth = 1e9;
        cfg.sites[2].disk_rate = 1e9;
        cfg.sites[2].latency = 0.0;
        cfg.sites[2].drd_time_ms = 0.0;
        assert_eq!(nearest_site(&cfg, 80e6), 2);
    }

    #[test]
    fn sweep_produces_three_points_and_the_static_arm_pays_nothing() {
        let cfg = GridConfig::generate(4, 23);
        let spec = WorkloadSpec { files: 4, mean_interarrival: 12.0, ..Default::default() };
        let r = run_economy(&cfg, &spec, 10, 2, 2, &EconomySweepOptions::default());
        assert_eq!(r.points.len(), 3);
        for p in &r.points {
            for a in [&p.static_placement, &p.economy] {
                assert!((0.0..=1.0).contains(&a.hit_rate_nearest));
                assert!((0.0..=1.0).contains(&a.completion_rate));
            }
            // Economy off ⇒ no stats, no background traffic.
            assert!(p.static_placement.report.economy.is_none());
            assert_eq!(p.static_placement.bytes_moved, 0.0);
            assert_eq!(p.static_placement.replicas_created, 0);
            // Economy on ⇒ stats present (possibly all-zero on a calm
            // shape, but the engine ran).
            assert!(p.economy.report.economy.is_some());
        }
    }
}
