//! Discovery-at-scale sweep (ISSUE 5): selection quality and query
//! cost of the hierarchical GIIS route as the grid grows and the soft
//! state ages.
//!
//! [`run_scale`] replays one request trace per point of a
//! `site count × refresh period` grid, twice on identically seeded
//! grids:
//!
//! * **fresh** — the direct route: every replica site's GRIS queried
//!   fresh at every selection (the always-fresh oracle of the
//!   information layer; its query bill grows with the replica set);
//! * **stale** — the hierarchical route: broad answers from GIIS
//!   registration snapshots refreshed every `refresh_period` simulated
//!   seconds, fresh drill-down only to the top
//!   [`ScaleOptions::drill_down`] summary-ranked candidates.
//!
//! `refresh_period = 0` re-pushes every site's snapshot at every
//! arrival — the parity anchor: the hierarchical route then selects
//! identically to the direct route (degradation exactly 1.0), while
//! still paying only `1 broad + K drill-downs` per request instead of
//! `N` site queries. Growing the period opens the informed-vs-stale
//! gap the EU-DataGrid experience report describes: summaries lag the
//! live bandwidth history, so the broker drills into (and picks)
//! yesterday's winners.

use crate::broker::selectors::{Selector, SelectorKind};
use crate::broker::RankPolicy;
use crate::config::GridConfig;
use crate::simnet::{Request, Workload, WorkloadSpec};
use crate::trace::{Ev, TraceHandle};

use super::grid::SimGrid;
use super::quality::{finish_report, pick_from_candidates, request_ad, QualityReport};

/// Per-sweep knobs (the axes come as explicit slices to [`run_scale`]).
#[derive(Debug, Clone)]
pub struct ScaleOptions {
    pub n_requests: usize,
    pub replicas_per_file: usize,
    pub warm: usize,
    /// Fresh GRIS drill-downs per selection on the hierarchical route.
    pub drill_down: usize,
    /// Registration TTL in simulated seconds (`f64::INFINITY` keeps
    /// every site discoverable however stale — the pure-staleness
    /// study; finite values add expiry churn on top).
    pub registration_ttl: f64,
    /// Flight recorder for request lifecycle roots (disabled by
    /// default). The handle is shared by every replay of the sweep and
    /// request ids restart per replay, so attach it when running a
    /// single cell (one site count × one refresh period).
    pub trace: TraceHandle,
}

impl Default for ScaleOptions {
    fn default() -> Self {
        ScaleOptions {
            n_requests: 40,
            replicas_per_file: 4,
            warm: 3,
            drill_down: 2,
            registration_ttl: f64::INFINITY,
            trace: TraceHandle::disabled(),
        }
    }
}

/// One (site count, refresh period) cell of the sweep.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub sites: usize,
    /// Soft-state refresh period (0 = refresh at every arrival).
    pub refresh_period: f64,
    /// Direct always-fresh selection on the identically seeded grid.
    pub fresh: QualityReport,
    /// GIIS-routed selection under this staleness.
    pub stale: QualityReport,
    /// `stale.mean_slowdown / fresh.mean_slowdown` — 1.0 at parity,
    /// growing as stale summaries misdirect the drill-down.
    pub degradation: f64,
    /// Fresh per-site GRIS queries the hierarchical route issued
    /// (drill-downs only — the per-request fan-out cost).
    pub drill_queries: u64,
    /// Broad GIIS lookups (one per selection).
    pub broad_queries: u64,
    /// GRIS searches spent re-snapshotting registrations (amortized
    /// background cost, paid per site per refresh, not per request).
    pub refresh_queries: u64,
    /// Per-site GRIS queries the direct route paid for the same trace.
    pub full_fanout_queries: u64,
    /// Hierarchical-route requests that found no live registration
    /// (TTL expiry) and could not select at all.
    pub undiscovered: u64,
}

/// The full sweep, row-major over `site_counts × refresh_periods`.
#[derive(Debug, Clone)]
pub struct ScaleReport {
    pub points: Vec<ScalePoint>,
}

/// One serial replay; `refresh_period: None` = direct fresh route.
struct ReplayOutcome {
    report: QualityReport,
    queries: u64,
    broad: u64,
    refreshes: u64,
    undiscovered: u64,
}

fn replay_serial(
    cfg: &GridConfig,
    spec: &WorkloadSpec,
    requests: &[Request],
    opts: &ScaleOptions,
    refresh_period: Option<f64>,
) -> ReplayOutcome {
    let mut grid = SimGrid::build(cfg, spec, opts.replicas_per_file, 64);
    grid.warm(opts.warm);
    let mut selector = Selector::new(SelectorKind::Forecast, cfg.seed);
    let policy = RankPolicy::ForecastBandwidth { engine: None };
    let (broker, hier) = match refresh_period {
        None => (grid.broker(policy), None),
        Some(_) => {
            let h = grid.hierarchy(opts.registration_ttl);
            (
                grid.broker_hier(policy, h.clone(), opts.drill_down),
                Some(h),
            )
        }
    };
    let t0 = grid.topo.now;
    let mut next_refresh = refresh_period
        .filter(|p| p.is_finite() && *p > 0.0)
        .map(|p| t0 + p);

    let mut durations = Vec::with_capacity(requests.len());
    let mut bandwidths = Vec::with_capacity(requests.len());
    let mut slowdowns = Vec::with_capacity(requests.len());
    let mut optimal_hits = 0usize;
    let mut queries = 0u64;
    let mut undiscovered = 0u64;
    for (i, req) in requests.iter().enumerate() {
        let id = i as u64;
        grid.topo.advance_to(t0 + req.at);
        grid.publish_dynamics();
        opts.trace.rec(grid.topo.now, id, Ev::Arrival);
        if let Some(h) = &hier {
            let mut dir = h.write().unwrap();
            dir.advance_to(grid.topo.now);
            match refresh_period {
                // Period 0: every site re-pushes at every arrival —
                // soft state is never stale (the parity anchor).
                Some(p) if p == 0.0 => dir.refresh_all(),
                Some(p) if p.is_finite() && p > 0.0 => {
                    // The serial replay only observes state at arrival
                    // instants, so a refresh whose nominal instant has
                    // passed executes *now* and is stamped *now* —
                    // the data it captures and the age it claims
                    // agree. (Stamping it back at the nominal instant
                    // would label arrival-fresh data as old and bias
                    // the staleness sweep.)
                    if let Some(at) = next_refresh {
                        if at <= grid.topo.now {
                            dir.refresh_all();
                            let mut next = at;
                            while next <= grid.topo.now {
                                next += p;
                            }
                            next_refresh = Some(next);
                        }
                    }
                }
                // Infinite period: the t0 push is all there ever is.
                _ => {}
            }
        }
        let logical = grid.files[req.file].clone();
        let size = grid.sizes[req.file];
        let ad = request_ad(req.min_bandwidth);
        let (cands, _trace) = broker.search(&logical, &ad).expect("search");
        if refresh_period.is_none() {
            queries += cands.len() as u64;
        }
        if opts.trace.on() {
            // Direct route: every candidate got a fresh GRIS query;
            // hierarchical: only the drill-down budget did.
            let drills = match refresh_period {
                None => cands.len() as u32,
                Some(_) => opts.drill_down.min(cands.len()) as u32,
            };
            opts.trace.rec(
                grid.topo.now,
                id,
                Ev::DiscoveryStart { placements: cands.len() as u32, drills },
            );
        }
        let pick = match pick_from_candidates(
            &grid,
            &broker,
            &mut selector,
            SelectorKind::Forecast,
            &cands,
            size,
            &ad,
        ) {
            Some(p) => p,
            None => {
                opts.trace.rec(grid.topo.now, id, Ev::RequestSkipped { reason: "no_replica" });
                undiscovered += 1;
                continue;
            }
        };
        let out = grid.ftp.fetch(&mut grid.topo, pick.pick_site, "client", size);
        if opts.trace.on() {
            let now = grid.topo.now;
            let name = grid.topo.site(pick.pick_site).cfg.name.clone();
            let candidates = cands.len() as u32;
            let dur = out.duration;
            opts.trace.with(|r| {
                let site = r.intern(&name);
                r.push(now, id, Ev::Selection { site, candidates });
                r.push(now, id, Ev::AnalyticAccess { site, transfer_s: dur });
                r.push(now + dur, id, Ev::RequestDone { transfer_s: dur });
            });
        }
        durations.push(out.duration);
        bandwidths.push(out.bandwidth);
        slowdowns.push(out.duration / pick.best_oracle.max(1e-9));
        if pick.pick_site == pick.best_site {
            optimal_hits += 1;
        }
    }
    let (broad, refreshes) = match &hier {
        Some(h) => {
            let stats = h.read().unwrap().stats();
            queries = stats.drill_downs;
            (stats.broad_queries, stats.refreshes)
        }
        None => (0, 0),
    };
    ReplayOutcome {
        report: finish_report("forecast", durations, &bandwidths, &slowdowns, optimal_hits),
        queries,
        broad,
        refreshes,
        undiscovered,
    }
}

/// Sweep `site_counts × refresh_periods` (see the module docs). Each
/// cell replays the same per-site-count trace on identically seeded
/// grids, so the fresh and stale columns differ only in what the
/// information layer told the broker.
pub fn run_scale(
    site_counts: &[usize],
    refresh_periods: &[f64],
    spec: &WorkloadSpec,
    opts: &ScaleOptions,
    seed: u64,
) -> ScaleReport {
    let mut points = Vec::new();
    for &n_sites in site_counts {
        let cfg = GridConfig::generate(n_sites, seed.wrapping_add(n_sites as u64));
        let requests = Workload::new(spec.clone(), cfg.seed).take(opts.n_requests);
        let fresh = replay_serial(&cfg, spec, &requests, opts, None);
        for &period in refresh_periods {
            let stale = replay_serial(&cfg, spec, &requests, opts, Some(period));
            let degradation = if fresh.report.mean_slowdown > 0.0 {
                stale.report.mean_slowdown / fresh.report.mean_slowdown
            } else {
                1.0
            };
            points.push(ScalePoint {
                sites: n_sites,
                refresh_period: period,
                degradation,
                drill_queries: stale.queries,
                broad_queries: stale.broad,
                refresh_queries: stale.refreshes,
                full_fanout_queries: fresh.queries,
                undiscovered: stale.undiscovered,
                fresh: fresh.report.clone(),
                stale: stale.report,
            });
        }
    }
    ScaleReport { points }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        WorkloadSpec { files: 6, mean_interarrival: 90.0, ..Default::default() }
    }

    #[test]
    fn parity_at_zero_staleness_across_site_counts() {
        // The acceptance anchor: with soft state refreshed at every
        // arrival, GIIS-routed selection equals direct-GRIS selection
        // bit-for-bit at every site count — while paying strictly
        // fewer per-request GRIS queries than the full fan-out.
        let opts = ScaleOptions { n_requests: 15, ..Default::default() };
        let r = run_scale(&[8, 12, 16], &[0.0], &spec(), &opts, 501);
        assert_eq!(r.points.len(), 3);
        for p in &r.points {
            assert_eq!(
                p.stale.mean_time, p.fresh.mean_time,
                "{} sites: hier route must reproduce direct selection exactly",
                p.sites
            );
            assert_eq!(p.stale.pct_optimal, p.fresh.pct_optimal);
            assert_eq!(p.degradation, 1.0);
            assert_eq!(p.undiscovered, 0);
            assert!(
                p.drill_queries < p.full_fanout_queries,
                "{} sites: drill {} !< full {}",
                p.sites,
                p.drill_queries,
                p.full_fanout_queries
            );
            assert_eq!(p.broad_queries, 15);
        }
    }

    #[test]
    fn stale_points_complete_and_report_the_gap() {
        let opts = ScaleOptions { n_requests: 15, ..Default::default() };
        let r = run_scale(&[10], &[0.0, 300.0, 1e9], &spec(), &opts, 502);
        assert_eq!(r.points.len(), 3);
        for p in &r.points {
            assert_eq!(p.stale.requests, 15, "TTL ∞ keeps every request discoverable");
            assert!(p.degradation.is_finite() && p.degradation > 0.0);
            assert!(p.drill_queries < p.full_fanout_queries);
        }
        // The gap is monotone-ish in expectation; at minimum the
        // never-refreshed point cannot beat the parity point's
        // oracle-relative slowdown by more than noise.
        let parity = &r.points[0];
        let stalest = &r.points[2];
        assert_eq!(parity.degradation, 1.0);
        assert!(
            stalest.stale.mean_slowdown >= parity.stale.mean_slowdown * 0.95,
            "stalest {} vs parity {}",
            stalest.stale.mean_slowdown,
            parity.stale.mean_slowdown
        );
    }

    #[test]
    fn expiry_makes_requests_undiscoverable() {
        let opts = ScaleOptions {
            n_requests: 12,
            registration_ttl: 1.0,
            ..Default::default()
        };
        // Registered once at t0, never refreshed, 1 s TTL: every
        // arrival after the first second finds nothing.
        let r = run_scale(&[8], &[1e18], &spec(), &opts, 503);
        let p = &r.points[0];
        assert!(p.undiscovered > 0);
        assert_eq!(p.stale.requests as u64 + p.undiscovered, 12);
    }

    #[test]
    fn deterministic_given_seed() {
        let opts = ScaleOptions { n_requests: 10, ..Default::default() };
        let a = run_scale(&[8], &[0.0, 600.0], &spec(), &opts, 504);
        let b = run_scale(&[8], &[0.0, 600.0], &spec(), &opts, 504);
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.stale.mean_time, y.stale.mean_time);
            assert_eq!(x.fresh.mean_time, y.fresh.mean_time);
            assert_eq!(x.drill_queries, y.drill_queries);
        }
    }
}
