//! Striped `store()` — replica creation that pushes one logical file
//! to several destination sites *in parallel*.
//!
//! The read path ([`super::scheduler`]) stripes disjoint ranges of one
//! file across sources; replica creation is the dual: every
//! destination needs the *whole* file, so the client pushes one full
//! copy per destination concurrently, all copies sharing the client's
//! uplink (`CoallocPolicy::client_downlink` models the client pipe in
//! both directions) while each destination's WAN link and disk bound
//! its own stream. Pushes move in `block_size` chunks so (a) each
//! chunk lands in the destination's [`HistoryStore`] as a write record
//! — feeding the Figure-4 `AvgWRBandwidth` attributes replica
//! placement ranks by — and (b) the fault surface is per block: a
//! destination that dies or stalls mid-push is dropped (its partial
//! copy is abandoned) without disturbing the other destinations.
//!
//! Space is committed ([`Topology::consume_space`]) only when a
//! destination receives its full copy, mirroring
//! [`crate::gridftp::GridFtp::store`]; abandoned partials are assumed
//! garbage-collected by the site. The caller registers completed
//! copies in the replica catalog — see
//! [`crate::broker::replication::ReplicaManager::create_replicas`].

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::config::CoallocPolicy;
use crate::gridftp::history::{Direction, TransferRecord};
use crate::gridftp::GridFtp;
use crate::simnet::{FlowSet, Topology};

/// One destination offered to the striped store.
#[derive(Debug, Clone)]
pub struct StoreTarget {
    /// Site name (resolved to a topology index at execution time).
    pub site: String,
    /// Physical URL the new replica will be registered under.
    pub url: String,
}

/// Per-destination outcome of a striped store.
#[derive(Debug, Clone)]
pub struct StoreStreamReport {
    pub site: String,
    pub site_index: usize,
    pub url: String,
    /// Blocks delivered to this destination.
    pub blocks: usize,
    /// Bytes delivered (== file size iff `completed`).
    pub bytes: f64,
    /// First-byte to last-block wall time for this destination.
    pub duration: f64,
    /// Mean delivered bandwidth over the push (bytes/s).
    pub mean_bandwidth: f64,
    /// Whether the full copy arrived (space committed, registrable).
    pub completed: bool,
    /// Bytes the commit actually consumed on the destination volume
    /// ([`Topology::consume_space`]'s clamped applied delta; 0 unless
    /// `completed`). The caller's deletion ledger reclaims exactly
    /// this amount.
    pub applied: f64,
}

/// Outcome of one striped replica-creation push.
#[derive(Debug, Clone)]
pub struct StoreOutcome {
    /// Size of the logical file (bytes pushed per destination).
    pub bytes_per_replica: f64,
    pub started_at: f64,
    /// Wall time to the last successful destination's completion.
    pub duration: f64,
    /// Destinations that received a full copy.
    pub completed: usize,
    /// Destinations lost mid-push (death or stall).
    pub failed: usize,
    pub reports: Vec<StoreStreamReport>,
}

impl StoreOutcome {
    /// Surface the store counters through a [`Metrics`] registry,
    /// symmetric with [`super::CoallocOutcome::record_metrics`].
    pub fn record_metrics(&self, m: &crate::metrics::Metrics) {
        m.counter("coalloc.stores").inc();
        m.counter("coalloc.store_replicas").add(self.completed as u64);
        m.counter("coalloc.store_failures").add(self.failed as u64);
        for r in &self.reports {
            m.counter(&format!("coalloc.store_bytes.{}", r.site)).add(r.bytes as u64);
            if !r.completed {
                m.counter(&format!("coalloc.failures.{}", r.site)).inc();
            }
        }
        m.histogram("coalloc.store_ns").observe_ns((self.duration * 1e9) as u64);
    }
}

struct Push {
    site: usize,
    target: StoreTarget,
    queue: VecDeque<usize>,
    /// (block id, flow id, assigned sim time) of the block in flight.
    current: Option<(usize, usize, f64)>,
    blocks_done: usize,
    bytes_done: f64,
    /// Space the completion commit actually consumed (clamped delta).
    applied: f64,
    first_at: f64,
    last_at: f64,
    finished: bool,
    failed: bool,
}

/// Push `bytes` of one logical file to every target in parallel.
/// Destinations that die ([`Topology::site_alive`]) or stall (one
/// block in flight longer than `policy.block_timeout`) are dropped and
/// reported as failed; the push as a whole succeeds if *any*
/// destination completes. Duplicate targets or unknown sites are an
/// error; zero targets or zero bytes is a no-op.
pub fn execute_store(
    topo: &mut Topology,
    ftp: &GridFtp,
    client: &str,
    targets: &[StoreTarget],
    bytes: f64,
    policy: &CoallocPolicy,
) -> Result<StoreOutcome> {
    let started_at = topo.now;
    let block = policy.block_size.max(1.0);
    let n_blocks = if bytes > 0.0 { (bytes / block).ceil() as usize } else { 0 };
    let block_len = |b: usize| (bytes - b as f64 * block).min(block).max(0.0);

    let mut pushes: Vec<Push> = Vec::with_capacity(targets.len());
    for t in targets {
        let site = match topo.index_of(&t.site) {
            Some(i) => i,
            None => bail!("store target names unknown site {:?}", t.site),
        };
        if pushes.iter().any(|p| p.site == site) {
            bail!("store target {:?} listed twice", t.site);
        }
        pushes.push(Push {
            site,
            target: t.clone(),
            queue: (0..n_blocks).collect(),
            current: None,
            blocks_done: 0,
            bytes_done: 0.0,
            applied: 0.0,
            first_at: started_at,
            last_at: started_at,
            finished: n_blocks == 0,
            failed: false,
        });
    }
    if pushes.is_empty() || n_blocks == 0 {
        return Ok(StoreOutcome {
            bytes_per_replica: bytes.max(0.0),
            started_at,
            duration: 0.0,
            completed: pushes.len(),
            failed: 0,
            reports: pushes
                .iter()
                .map(|p| StoreStreamReport {
                    site: p.target.site.clone(),
                    site_index: p.site,
                    url: p.target.url.clone(),
                    blocks: 0,
                    bytes: 0.0,
                    duration: 0.0,
                    mean_bandwidth: 0.0,
                    completed: true,
                    applied: 0.0,
                })
                .collect(),
        });
    }

    // Register each push as an in-flight transfer (GRIS `load`, link
    // sharing), exactly like the read path's streams.
    for p in &pushes {
        topo.begin_transfer(p.site);
    }

    let mut flows = FlowSet::new(policy.client_downlink);
    let mut flow_owner: Vec<usize> = Vec::new();
    let tick = policy.tick.max(1e-3);
    let max_ticks = 2_000_000usize;

    // One pass of the per-tick duties, shared by the tick top and the
    // completion sub-loop: fail lost destinations, start idle blocks.
    fn dispatch(
        pushes: &mut [Push],
        topo: &mut Topology,
        flows: &mut FlowSet,
        flow_owner: &mut Vec<usize>,
        block_len: &dyn Fn(usize) -> f64,
        timeout: f64,
    ) {
        for i in 0..pushes.len() {
            if pushes[i].finished || pushes[i].failed {
                continue;
            }
            // Fault surface: the destination vanished or one block has
            // been in flight past the stall timeout.
            let dead = !topo.site_alive(pushes[i].site);
            let stalled = matches!(
                pushes[i].current,
                Some((_, _, at)) if topo.now - at > timeout
            );
            if dead || stalled {
                let p = &mut pushes[i];
                p.failed = true;
                if let Some((_, fid, _)) = p.current.take() {
                    flows.cancel(fid);
                }
                topo.end_transfer(p.site);
                continue;
            }
            if pushes[i].current.is_some() {
                continue;
            }
            match pushes[i].queue.pop_front() {
                Some(b) => {
                    let len = block_len(b);
                    // Per-block setup: connection latency + the write
                    // seek (`dwrTime`) every chunk pays.
                    let lead = {
                        let sc = &topo.site(pushes[i].site).cfg;
                        sc.latency + sc.dwr_time_ms / 1e3
                    };
                    let fid = flows.add(topo, pushes[i].site, len, lead);
                    flow_owner.push(i);
                    if pushes[i].blocks_done == 0 {
                        pushes[i].first_at = topo.now;
                    }
                    pushes[i].current = Some((b, fid, topo.now));
                }
                None => {
                    // Full copy delivered: commit the space, retire.
                    let p = &mut pushes[i];
                    p.finished = true;
                    topo.end_transfer(p.site);
                    p.applied = topo.consume_space(p.site, p.bytes_done);
                }
            }
        }
    }

    'ticks: for _ in 0..max_ticks {
        dispatch(&mut pushes, topo, &mut flows, &mut flow_owner, &block_len, policy.block_timeout);
        if pushes.iter().all(|p| p.finished || p.failed) {
            break;
        }
        let mut tick_left = tick;
        while tick_left > 1e-12 {
            let (used, completions) = flows.advance_some(topo, tick_left);
            tick_left -= used;
            if completions.is_empty() {
                break;
            }
            for c in completions {
                let owner = flow_owner[c.flow];
                let p = &mut pushes[owner];
                let (b, fid, assigned_at) = match p.current.take() {
                    Some(cur) => cur,
                    None => continue,
                };
                debug_assert_eq!(fid, c.flow);
                let len = block_len(b);
                let duration = (c.at - assigned_at).max(1e-9);
                ftp.record(
                    p.site,
                    TransferRecord {
                        at: assigned_at,
                        peer: client.to_string(),
                        direction: Direction::Write,
                        bytes: len,
                        duration,
                    },
                );
                p.blocks_done += 1;
                p.bytes_done += len;
                p.last_at = c.at;
            }
            if tick_left > 1e-12 {
                dispatch(
                    &mut pushes, topo, &mut flows, &mut flow_owner, &block_len,
                    policy.block_timeout,
                );
            }
        }
        if pushes.iter().all(|p| p.finished || p.failed) {
            break 'ticks;
        }
    }

    if !pushes.iter().all(|p| p.finished || p.failed) {
        for p in &pushes {
            if !p.finished && !p.failed {
                topo.end_transfer(p.site);
            }
        }
        bail!("striped store did not converge within the tick budget");
    }

    let completed = pushes.iter().filter(|p| p.finished).count();
    // Report the time to the last *successful* copy.
    let duration = pushes
        .iter()
        .filter(|p| p.finished)
        .map(|p| p.last_at - started_at)
        .fold(0.0, f64::max);
    Ok(StoreOutcome {
        bytes_per_replica: bytes,
        started_at,
        duration,
        completed,
        failed: pushes.len() - completed,
        reports: pushes
            .iter()
            .map(|p| StoreStreamReport {
                site: p.target.site.clone(),
                site_index: p.site,
                url: p.target.url.clone(),
                blocks: p.blocks_done,
                bytes: p.bytes_done,
                duration: if p.blocks_done > 0 { p.last_at - p.first_at } else { 0.0 },
                mean_bandwidth: if p.last_at > p.first_at {
                    p.bytes_done / (p.last_at - p.first_at)
                } else {
                    0.0
                },
                completed: p.finished,
                applied: p.applied,
            })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GridConfig;
    use crate::simnet::FaultKind;

    fn flat_grid(n: usize, bw: f64) -> (GridConfig, Topology, GridFtp) {
        let mut cfg = GridConfig::generate(n, 23);
        for s in &mut cfg.sites {
            s.wan_bandwidth = bw;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
            s.disk_rate = 1e9;
            s.dwr_time_ms = 0.0;
            s.drd_time_ms = 0.0;
        }
        let topo = Topology::build(&cfg);
        let ftp = GridFtp::new(&topo, 32);
        (cfg, topo, ftp)
    }

    fn targets(cfg: &GridConfig, n: usize) -> Vec<StoreTarget> {
        (0..n)
            .map(|i| StoreTarget {
                site: cfg.sites[i].name.clone(),
                url: format!("gsiftp://{}/f", cfg.sites[i].name),
            })
            .collect()
    }

    #[test]
    fn every_destination_gets_a_full_instrumented_copy() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy { block_size: 4e6, tick: 1.0, ..Default::default() };
        let space_before: Vec<f64> =
            (0..3).map(|i| topo.site(i).available_space()).collect();
        let out = execute_store(&mut topo, &ftp, "client", &targets(&cfg, 3), 20e6, &policy)
            .unwrap();
        assert_eq!(out.completed, 3);
        assert_eq!(out.failed, 0);
        for r in &out.reports {
            assert!(r.completed);
            assert_eq!(r.blocks, 5);
            assert!((r.bytes - 20e6).abs() < 1.0);
            // Write instrumentation landed in the history store.
            let h = ftp.history(r.site_index);
            let h = h.read().unwrap();
            assert_eq!(h.wr.count, 5);
            assert_eq!(h.rd.count, 0);
            // Space was committed on completion.
            assert!(
                (space_before[r.site_index] - topo.site(r.site_index).available_space()
                    - 20e6)
                    .abs()
                    < 1.0
            );
        }
        // Parallel: pushes overlapped instead of running back to back.
        // One copy over a self-shared 0.5e6 B/s link takes 40 s.
        assert!(out.duration < 2.0 * 40.0 + 1.0, "duration {}", out.duration);
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn dying_destination_is_dropped_not_fatal() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy { block_size: 4e6, tick: 1.0, ..Default::default() };
        let avail0 = topo.site(0).available_space();
        // Destination 0 dies a third of the way into its copy.
        topo.schedule_fault(0, 15.0, FaultKind::ReplicaDeath);
        let out = execute_store(&mut topo, &ftp, "client", &targets(&cfg, 3), 20e6, &policy)
            .unwrap();
        assert_eq!(out.completed, 2);
        assert_eq!(out.failed, 1);
        let lost = &out.reports[0];
        assert!(!lost.completed);
        assert!(lost.bytes < 20e6);
        // No space committed for the abandoned partial.
        assert!((topo.site(0).available_space() - avail0).abs() < 1.0);
        // Survivors are whole.
        for r in &out.reports[1..] {
            assert!(r.completed);
            assert!((r.bytes - 20e6).abs() < 1.0);
        }
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn uplink_cap_serializes_the_copies() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let capped = CoallocPolicy {
            block_size: 4e6,
            tick: 1.0,
            client_downlink: 0.5e6, // client pipe half of one link share
            ..Default::default()
        };
        let out =
            execute_store(&mut topo, &ftp, "c", &targets(&cfg, 2), 10e6, &capped).unwrap();
        // 2 × 10e6 bytes through a 0.5e6 B/s pipe ⇒ ≥ 40 s.
        assert!(out.duration >= 40.0 - 1e-6, "duration {}", out.duration);
        assert_eq!(out.completed, 2);
    }

    #[test]
    fn store_outcome_records_metrics() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy { block_size: 4e6, tick: 1.0, ..Default::default() };
        topo.schedule_fault(1, 5.0, FaultKind::ReplicaDeath);
        let out = execute_store(&mut topo, &ftp, "c", &targets(&cfg, 2), 12e6, &policy)
            .unwrap();
        let m = crate::metrics::Metrics::new();
        out.record_metrics(&m);
        assert_eq!(m.counter("coalloc.stores").get(), 1);
        assert_eq!(m.counter("coalloc.store_replicas").get(), 1);
        assert_eq!(m.counter("coalloc.store_failures").get(), 1);
        let dead = &out.reports[1].site;
        assert_eq!(m.counter(&format!("coalloc.failures.{dead}")).get(), 1);
    }

    #[test]
    fn degenerate_inputs() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy::default();
        // No targets.
        let out = execute_store(&mut topo, &ftp, "c", &[], 5e6, &policy).unwrap();
        assert_eq!(out.completed, 0);
        // Zero bytes: trivially complete everywhere.
        let out =
            execute_store(&mut topo, &ftp, "c", &targets(&cfg, 2), 0.0, &policy).unwrap();
        assert_eq!(out.completed, 2);
        assert_eq!(out.duration, 0.0);
        // Unknown site.
        let ghost = [StoreTarget { site: "ghost".into(), url: "u".into() }];
        assert!(execute_store(&mut topo, &ftp, "c", &ghost, 1e6, &policy).is_err());
        // Duplicate target.
        let dup = [
            StoreTarget { site: cfg.sites[0].name.clone(), url: "a".into() },
            StoreTarget { site: cfg.sites[0].name.clone(), url: "b".into() },
        ];
        assert!(execute_store(&mut topo, &ftp, "c", &dup, 1e6, &policy).is_err());
    }
}
