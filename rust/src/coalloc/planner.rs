//! Stripe planning: turn a ranked candidate set + per-source bandwidth
//! predictions into an initial contiguous byte-range assignment.
//!
//! The plan apportions whole blocks (the scheduler's transfer unit)
//! proportionally to each source's predicted bandwidth using
//! largest-remainder rounding, so the assignment partitions the file
//! exactly and the fastest predicted source gets the most bytes. The
//! plan is only the *opening position* — the chunk scheduler rebalances
//! against reality as links drift.
//!
//! **Downlink awareness:** when the policy carries a finite
//! `client_downlink`, the planner water-fills that cap over the
//! admitted sources fastest-first — each source contributes at most
//! what remains of the client's absorption capacity, and a source whose
//! whole contribution would be clipped to zero is not striped at all
//! (no phantom parallelism: extra streams the client pipe cannot feed
//! would only add per-block setup latency). Shares are proportional to
//! the *clipped* bandwidths, so the partition matches the throughput
//! each stream can actually sustain once the scheduler's
//! [`crate::simnet::FlowSet`] enforces the same cap at execution time.

use crate::config::CoallocPolicy;

/// One source replica offered to the planner.
#[derive(Debug, Clone)]
pub struct StripeSource {
    /// Site name (resolved to a topology index at execution time).
    pub site: String,
    /// Physical URL of the replica.
    pub url: String,
    /// Predicted read bandwidth from this source (bytes/s).
    pub predicted_bw: f64,
}

/// A contiguous byte-range assignment for one stream.
#[derive(Debug, Clone)]
pub struct StripeAssignment {
    pub source: StripeSource,
    /// First byte of the range.
    pub offset: f64,
    /// Length of the range in bytes.
    pub bytes: f64,
    /// First block index (inclusive).
    pub first_block: usize,
    /// Number of whole blocks in the range.
    pub blocks: usize,
    /// Planned fraction of the file.
    pub share: f64,
}

/// The full stripe plan for one logical file.
#[derive(Debug, Clone)]
pub struct StripePlan {
    pub total_bytes: f64,
    pub block_size: f64,
    /// Total number of blocks (last one may be partial).
    pub n_blocks: usize,
    /// Per-stream assignments, in block order (offsets ascending).
    pub assignments: Vec<StripeAssignment>,
}

impl StripePlan {
    /// Byte range of block `i`: (offset, length).
    pub fn block_range(&self, i: usize) -> (f64, f64) {
        let offset = i as f64 * self.block_size;
        let len = (self.total_bytes - offset).min(self.block_size).max(0.0);
        (offset, len)
    }

    /// Expected completion time if every source delivered exactly its
    /// predicted bandwidth (the planner's own objective value).
    pub fn predicted_makespan(&self) -> f64 {
        self.assignments
            .iter()
            .map(|a| {
                if a.bytes <= 0.0 {
                    0.0
                } else if a.source.predicted_bw <= 0.0 {
                    f64::INFINITY
                } else {
                    a.bytes / a.source.predicted_bw
                }
            })
            .fold(0.0, f64::max)
    }
}

/// Compute the initial stripe plan for `total_bytes` across `sources`.
///
/// Sources beyond `policy.max_streams` are dropped (keeping the
/// highest-predicted ones); non-positive predictions fall back to an
/// equal split so a history-less grid still stripes. Returns an empty
/// plan for an empty source list or a zero-byte file.
pub fn plan_stripes(
    sources: &[StripeSource],
    total_bytes: f64,
    policy: &CoallocPolicy,
) -> StripePlan {
    let block = policy.block_size.max(1.0);
    let n_blocks = if total_bytes > 0.0 {
        (total_bytes / block).ceil() as usize
    } else {
        0
    };
    let mut plan = StripePlan {
        total_bytes: total_bytes.max(0.0),
        block_size: block,
        n_blocks,
        assignments: Vec::new(),
    };
    if sources.is_empty() || n_blocks == 0 {
        return plan;
    }

    // Keep the top `max_streams` sources by predicted bandwidth
    // (stable: ties keep the caller's rank order).
    let mut order: Vec<usize> = (0..sources.len()).collect();
    order.sort_by(|&a, &b| {
        sources[b]
            .predicted_bw
            .partial_cmp(&sources[a].predicted_bw)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    order.truncate(policy.max_streams.max(1).min(n_blocks.max(1)));
    // Straggler guard: adding a source only helps if it can finish at
    // least one block before the sources already included could have
    // moved the whole file. Greedily admit fastest-first while that
    // holds; a replica 100x slower than the rest would otherwise turn
    // the stripe's makespan into its single-block time.
    if order.iter().any(|&i| sources[i].predicted_bw > 0.0) {
        let block_bytes = block.min(total_bytes);
        let mut kept: Vec<usize> = Vec::with_capacity(order.len());
        let mut sum_bw = 0.0;
        for &i in &order {
            let bw = sources[i].predicted_bw;
            if bw <= 0.0 {
                continue;
            }
            if kept.is_empty() || block_bytes / bw <= total_bytes / sum_bw {
                sum_bw += bw;
                kept.push(i);
            }
        }
        if !kept.is_empty() {
            order = kept;
        }
    }
    // Downlink clipping: water-fill the client's absorption capacity
    // over the admitted sources fastest-first (`order` is still sorted
    // by descending prediction here). Each source's *effective*
    // bandwidth is what remains of the cap; sources clipped to zero are
    // dropped entirely.
    let mut eff: Vec<(usize, f64)> = Vec::with_capacity(order.len());
    let cap = policy.client_downlink;
    if cap.is_finite() && order.iter().any(|&i| sources[i].predicted_bw > 0.0) {
        let mut remaining = cap.max(0.0);
        for &i in &order {
            if remaining <= 0.0 {
                break;
            }
            let e = sources[i].predicted_bw.max(0.0).min(remaining);
            eff.push((i, e));
            remaining -= e;
        }
        if eff.is_empty() {
            // Degenerate cap (≤ 0): a single stream still moves bytes.
            eff.push((order[0], sources[order[0]].predicted_bw.max(0.0)));
        }
    } else {
        eff.extend(order.iter().map(|&i| (i, sources[i].predicted_bw.max(0.0))));
    }
    // Assign ranges in the caller's original order so offsets follow
    // the broker's ranking, not the bandwidth sort.
    eff.sort_unstable_by_key(|&(i, _)| i);
    let order: Vec<usize> = eff.iter().map(|&(i, _)| i).collect();

    let weights: Vec<f64> = {
        let raw: Vec<f64> = eff.iter().map(|&(_, e)| e).collect();
        let sum: f64 = raw.iter().sum();
        if sum <= 0.0 {
            vec![1.0 / order.len() as f64; order.len()]
        } else {
            raw.iter().map(|w| w / sum).collect()
        }
    };

    // Largest-remainder apportionment of whole blocks.
    let quotas: Vec<f64> = weights.iter().map(|w| w * n_blocks as f64).collect();
    let mut counts: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let assigned: usize = counts.iter().sum();
    let mut remainders: Vec<(usize, f64)> = quotas
        .iter()
        .enumerate()
        .map(|(i, q)| (i, q - q.floor()))
        .collect();
    remainders.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    for k in 0..(n_blocks - assigned) {
        counts[remainders[k % remainders.len()].0] += 1;
    }

    let mut next_block = 0usize;
    for (pos, &src_idx) in order.iter().enumerate() {
        let blocks = counts[pos];
        if blocks == 0 {
            // A downlink-clipped sliver whose quota rounded to nothing:
            // a zero-block stream would still open a connection and
            // join the work-stealing pool — exactly the phantom
            // parallelism the clipping exists to prevent.
            continue;
        }
        let offset = next_block as f64 * block;
        let end = ((next_block + blocks) as f64 * block).min(plan.total_bytes);
        plan.assignments.push(StripeAssignment {
            source: sources[src_idx].clone(),
            offset,
            bytes: (end - offset).max(0.0),
            first_block: next_block,
            blocks,
            share: weights[pos],
        });
        next_block += blocks;
    }
    debug_assert_eq!(next_block, n_blocks);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(site: &str, bw: f64) -> StripeSource {
        StripeSource {
            site: site.into(),
            url: format!("gsiftp://{site}/f"),
            predicted_bw: bw,
        }
    }

    fn policy(block: f64, k: usize) -> CoallocPolicy {
        CoallocPolicy { block_size: block, max_streams: k, ..Default::default() }
    }

    #[test]
    fn partitions_the_file_exactly() {
        let p = plan_stripes(
            &[src("a", 3e6), src("b", 1e6), src("c", 2e6)],
            100e6,
            &policy(8e6, 4),
        );
        assert_eq!(p.n_blocks, 13);
        let total_blocks: usize = p.assignments.iter().map(|a| a.blocks).sum();
        assert_eq!(total_blocks, 13);
        let total_bytes: f64 = p.assignments.iter().map(|a| a.bytes).sum();
        assert!((total_bytes - 100e6).abs() < 1.0);
        // Ranges are contiguous and ascending.
        let mut cursor = 0.0;
        for a in &p.assignments {
            assert_eq!(a.offset, cursor);
            cursor += a.blocks as f64 * p.block_size;
        }
    }

    #[test]
    fn shares_proportional_to_prediction() {
        let p = plan_stripes(
            &[src("fast", 8e6), src("slow", 2e6)],
            200e6,
            &policy(4e6, 2),
        );
        let fast = p.assignments.iter().find(|a| a.source.site == "fast").unwrap();
        let slow = p.assignments.iter().find(|a| a.source.site == "slow").unwrap();
        assert_eq!(p.n_blocks, 50);
        assert_eq!(fast.blocks, 40);
        assert_eq!(slow.blocks, 10);
        assert!((fast.share - 0.8).abs() < 1e-9);
        // Balanced plan: both streams predict the same finish time.
        let tf = fast.bytes / fast.source.predicted_bw;
        let ts = slow.bytes / slow.source.predicted_bw;
        assert!((tf - ts).abs() / tf < 0.1, "tf {tf} ts {ts}");
        assert!((p.predicted_makespan() - tf.max(ts)).abs() < 1e-9);
    }

    #[test]
    fn max_streams_keeps_the_fastest() {
        let p = plan_stripes(
            &[src("a", 1e6), src("b", 9e6), src("c", 5e6), src("d", 7e6)],
            64e6,
            &policy(4e6, 2),
        );
        let sites: Vec<&str> =
            p.assignments.iter().map(|a| a.source.site.as_str()).collect();
        assert_eq!(sites, vec!["b", "d"]);
    }

    #[test]
    fn straggler_sources_are_dropped() {
        // The crawling replica cannot finish even one block before the
        // fast one could move the whole file — admitting it would let
        // its single-block time dominate the makespan.
        let p = plan_stripes(
            &[src("fast", 2e6), src("crawl", 20e3)],
            80e6,
            &policy(8e6, 4),
        );
        let sites: Vec<&str> =
            p.assignments.iter().map(|a| a.source.site.as_str()).collect();
        assert_eq!(sites, vec!["fast"]);
        assert_eq!(p.assignments[0].blocks, p.n_blocks);
        // A merely-slower (not pathological) source still participates.
        let p = plan_stripes(
            &[src("fast", 2e6), src("slower", 0.7e6)],
            80e6,
            &policy(8e6, 4),
        );
        assert_eq!(p.assignments.len(), 2);
    }

    #[test]
    fn stripes_clip_to_the_client_downlink() {
        // Four 1 MB/s sources behind a 1.5 MB/s client pipe: only two
        // streams can be fed — the second clipped to the 0.5 MB/s that
        // remains of the cap — and the other two are phantom
        // parallelism the planner must not schedule.
        let mut policy = policy(4e6, 4);
        policy.client_downlink = 1.5e6;
        let p = plan_stripes(
            &[src("a", 1e6), src("b", 1e6), src("c", 1e6), src("d", 1e6)],
            120e6,
            &policy,
        );
        assert_eq!(p.assignments.len(), 2, "downlink admits only two streams");
        // Shares follow the clipped bandwidths: 1.0/1.5 and 0.5/1.5.
        assert!((p.assignments[0].share - 2.0 / 3.0).abs() < 1e-9);
        assert!((p.assignments[1].share - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(p.assignments[0].blocks, 20);
        assert_eq!(p.assignments[1].blocks, 10);
        // The plan still partitions the file exactly.
        let total: f64 = p.assignments.iter().map(|a| a.bytes).sum();
        assert!((total - 120e6).abs() < 1.0);
    }

    #[test]
    fn clipped_sliver_never_becomes_a_zero_block_stream() {
        // Cap 3.01e6 over four 1e6 sources: the fourth source's
        // water-fill share is a 0.01e6 sliver whose block quota rounds
        // to zero — it must not appear in the plan at all.
        let mut policy = policy(4e6, 4);
        policy.client_downlink = 3.01e6;
        let p = plan_stripes(
            &[src("a", 1e6), src("b", 1e6), src("c", 1e6), src("d", 1e6)],
            120e6,
            &policy,
        );
        assert!(p.assignments.iter().all(|a| a.blocks > 0), "{:?}", p.assignments);
        let total: usize = p.assignments.iter().map(|a| a.blocks).sum();
        assert_eq!(total, p.n_blocks);
    }

    #[test]
    fn ample_downlink_leaves_the_plan_unclipped() {
        let srcs = [src("a", 3e6), src("b", 1e6)];
        let uncapped = plan_stripes(&srcs, 80e6, &policy(8e6, 4));
        let mut roomy = policy(8e6, 4);
        roomy.client_downlink = 100e6; // far above the 4e6 aggregate
        let capped = plan_stripes(&srcs, 80e6, &roomy);
        assert_eq!(uncapped.assignments.len(), capped.assignments.len());
        for (u, c) in uncapped.assignments.iter().zip(&capped.assignments) {
            assert_eq!(u.blocks, c.blocks);
            assert!((u.share - c.share).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_downlink_still_moves_bytes_on_one_stream() {
        let mut policy = policy(4e6, 4);
        policy.client_downlink = 0.0;
        let p = plan_stripes(&[src("a", 2e6), src("b", 1e6)], 40e6, &policy);
        assert_eq!(p.assignments.len(), 1);
        assert_eq!(p.assignments[0].source.site, "a");
        assert_eq!(p.assignments[0].blocks, p.n_blocks);
    }

    #[test]
    fn zero_predictions_fall_back_to_equal_split() {
        let p = plan_stripes(
            &[src("a", 0.0), src("b", 0.0)],
            40e6,
            &policy(10e6, 2),
        );
        assert_eq!(p.assignments[0].blocks, 2);
        assert_eq!(p.assignments[1].blocks, 2);
    }

    #[test]
    fn degenerate_inputs() {
        let p = plan_stripes(&[], 10e6, &policy(1e6, 4));
        assert!(p.assignments.is_empty());
        let p = plan_stripes(&[src("a", 1e6)], 0.0, &policy(1e6, 4));
        assert!(p.assignments.is_empty());
        assert_eq!(p.n_blocks, 0);
        // One tiny file: a single stream gets the single block.
        let p = plan_stripes(&[src("a", 1e6), src("b", 2e6)], 100.0, &policy(1e6, 4));
        assert_eq!(p.n_blocks, 1);
        let total: usize = p.assignments.iter().map(|a| a.blocks).sum();
        assert_eq!(total, 1);
        assert_eq!(p.block_range(0), (0.0, 100.0));
    }

    #[test]
    fn last_block_is_partial() {
        let p = plan_stripes(&[src("a", 1e6)], 25e6, &policy(10e6, 1));
        assert_eq!(p.n_blocks, 3);
        assert_eq!(p.block_range(2), (20e6, 5e6));
        assert_eq!(p.assignments[0].bytes, 25e6);
    }
}
