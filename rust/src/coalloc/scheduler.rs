//! The chunk scheduler: executes a [`StripePlan`] over the simulated
//! grid as one co-allocated transfer — expressed, since ISSUE 4, as an
//! event-driven **session** on the `simnet` kernel rather than a
//! private lockstep loop.
//!
//! Each assignment becomes a *stream* pinned to one replica site. A
//! stream pulls blocks from its own queue; the streams' current blocks
//! are flows in a [`simnet::FlowSet`] — the session's own set when run
//! through [`execute`], or a *shared, grid-wide* set when driven by an
//! [`crate::simnet::Engine`] the session coexists on with other
//! sessions and single-best fetches (each session gets its own
//! downlink group, so clients cap independently while still contending
//! on site links). The driver forwards the kernel's
//! [`crate::simnet::Signal::FlowDone`] events to
//! [`CoallocSession::on_flow_done`] and fires
//! [`CoallocSession::step`] at `CoallocPolicy::tick` maintenance
//! timers; the session reacts by re-dispatching freed streams at the
//! exact completion instants. When a stream drains its queue it
//! *steals* the tail half of the largest backlog among its peers
//! (policy `rebalance_threshold` gates the steal) — a slowing source
//! sheds blocks to faster ones without any central re-planning, and
//! because the rates it observes include *external* contention, the
//! same mechanism rebalances away from sites loaded by other clients.
//!
//! **Failover state machine.** A stream is `running → finished` in the
//! steady state. When its source *dies* (control channel down,
//! [`Topology::site_alive`]) or *stalls* (one block in flight longer
//! than `CoallocPolicy::block_timeout`), the stream transitions to
//! `failed`: its in-flight flow is cancelled, the block is pushed back
//! with a retry charged, its transfer slot is released, and its whole
//! backlog becomes an *orphan queue* that survivors steal from with no
//! backlog floor or rate gate (the usual stealing discipline, minus the
//! gates — orphans must move). Streams that had already retired are
//! revived so orphans always find a live adopter. The transfer fails
//! fast when failover is disabled (`max_block_retries = 0`), when one
//! block exhausts its retry budget, or when no live source remains —
//! and a final integrity check asserts every byte range was delivered
//! exactly once before the outcome is reported.
//!
//! Every completed block is instrumented as a [`TransferRecord`] into
//! the source site's `HistoryStore` via [`GridFtp::record`] — the same
//! store the site's GRIS providers publish from — so co-allocated
//! traffic feeds the selection history exactly like single-source
//! fetches do (paper §3.2).

use std::collections::{BTreeMap, VecDeque};

use anyhow::{bail, Result};

use crate::config::CoallocPolicy;
use crate::gridftp::history::{Direction, TransferRecord};
use crate::gridftp::GridFtp;
use crate::simnet::{Completion, Engine, FlowSet, Signal, Topology};
use crate::trace::{Ev, ReqId, TraceHandle};

use super::planner::StripePlan;

/// Per-stream outcome.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub site: String,
    pub site_index: usize,
    /// Blocks this stream delivered (own + stolen).
    pub blocks: usize,
    /// Blocks it delivered that were stolen from peers.
    pub stolen: usize,
    /// Bytes delivered.
    pub bytes: f64,
    /// Mean delivered bandwidth over the stream's busy time (bytes/s).
    pub mean_bandwidth: f64,
    /// Blocks this stream had in flight when its source died/stalled.
    pub failures: usize,
    /// Whether the stream ended in the `failed` state (source lost).
    pub failed: bool,
}

/// Outcome of one co-allocated transfer.
#[derive(Debug, Clone)]
pub struct CoallocOutcome {
    pub bytes: f64,
    /// Wall (simulated) time from start to last block completion.
    pub duration: f64,
    pub started_at: f64,
    /// bytes / duration.
    pub aggregate_bandwidth: f64,
    /// Total steal events (a steal moves ≥1 block between queues).
    pub steals: usize,
    /// Streams that failed over (source died or stalled mid-transfer).
    pub failovers: usize,
    /// Blocks re-queued off failed sources (in-flight + unscheduled).
    pub blocks_requeued: usize,
    /// Total retry charges across all blocks (= in-flight blocks
    /// cancelled by failovers).
    pub retries_total: usize,
    /// Highest per-block retry count observed (≤ `max_block_retries`).
    pub retries_peak: usize,
    pub streams: Vec<StreamReport>,
}

impl CoallocOutcome {
    /// Surface this outcome's counters through a [`Metrics`] registry
    /// (ROADMAP open item): transfer/steal/failover counts, blocks
    /// stolen and re-queued, per-source bytes and failures, and the
    /// completion time as a histogram sample. Simulated seconds are
    /// recorded as nanoseconds so the existing histogram quantile
    /// machinery applies unchanged.
    pub fn record_metrics(&self, m: &crate::metrics::Metrics) {
        m.counter("coalloc.transfers").inc();
        m.counter("coalloc.steal_events").add(self.steals as u64);
        m.counter("coalloc.bytes").add(self.bytes as u64);
        m.counter("coalloc.failovers").add(self.failovers as u64);
        m.counter("coalloc.blocks_requeued").add(self.blocks_requeued as u64);
        m.counter("coalloc.retries").add(self.retries_total as u64);
        m.histogram("coalloc.completion_ns")
            .observe_ns((self.duration * 1e9) as u64);
        for s in &self.streams {
            m.counter("coalloc.blocks_stolen").add(s.stolen as u64);
            m.counter(&format!("coalloc.bytes.{}", s.site)).add(s.bytes as u64);
            m.counter(&format!("coalloc.blocks.{}", s.site)).add(s.blocks as u64);
            if s.failures > 0 || s.failed {
                m.counter(&format!("coalloc.failures.{}", s.site))
                    .add(s.failures.max(1) as u64);
            }
        }
    }
}

struct Stream {
    site: usize,
    site_name: String,
    queue: VecDeque<usize>,
    /// (block id, flow id, assigned sim time) of the block in flight.
    current: Option<(usize, usize, f64)>,
    blocks_done: usize,
    stolen_done: usize,
    bytes_done: f64,
    busy_time: f64,
    /// Running bandwidth estimate: the planner's prediction, folded
    /// with observed per-block throughput (EWMA). 0 = unknown.
    est_bw: f64,
    finished: bool,
    /// Source died or stalled; the queue is orphaned (steal-only).
    failed: bool,
    /// Blocks this stream failed to deliver (cancelled in flight).
    failures: usize,
}

impl Stream {
    /// Whether this stream currently holds a transfer slot
    /// (`begin_transfer`d and neither retired nor failed).
    fn active(&self) -> bool {
        !self.finished && !self.failed
    }
}

/// Release the transfer slot of every still-active stream (error
/// paths; completed/failed streams released their slot already).
fn release_active(streams: &[Stream], topo: &mut Topology) {
    for s in streams {
        if s.active() {
            topo.end_transfer(s.site);
        }
    }
}

/// One co-allocated transfer as an event-driven state machine on the
/// `simnet` kernel. The session owns its streams, ledger and counters;
/// the flows live in a caller-provided [`FlowSet`] (the session's own
/// downlink `group` within it), so several sessions — and unrelated
/// single-best fetches — coexist on one grid-wide set. Drive it by
/// forwarding [`Signal::FlowDone`] events to
/// [`CoallocSession::on_flow_done`] and firing
/// [`CoallocSession::step`] at `CoallocPolicy::tick` maintenance
/// timers; collect the result with [`CoallocSession::outcome`] once
/// [`CoallocSession::is_done`]. [`execute`] wraps all of that for the
/// one-transfer-alone case.
pub struct CoallocSession {
    streams: Vec<Stream>,
    plan: StripePlan,
    policy: CoallocPolicy,
    client: String,
    /// Downlink group this session's flows occupy in the shared set.
    group: usize,
    /// Live flow id → stream index (ids are global to the shared set).
    flow_to_stream: BTreeMap<usize, usize>,
    /// block id → the stream originally assigned it by the planner, so
    /// a delivery counts as "stolen" exactly when someone else's block
    /// lands (even after multi-hop or steal-back churn).
    planned_owner: Vec<usize>,
    /// Exactly-once delivery ledger + per-block failover retry counts.
    delivered: Vec<bool>,
    retries: Vec<usize>,
    failovers: usize,
    blocks_requeued: usize,
    steals: usize,
    started_at: f64,
    finish_at: f64,
    min_steal: usize,
    /// Terminal error (sticky); `outcome` surfaces it.
    err: Option<anyhow::Error>,
    done: bool,
    /// Flight recorder (disabled by default; see [`crate::trace`]).
    trace: TraceHandle,
    /// Request id the recorder files this session's block events under.
    trace_req: ReqId,
}

impl CoallocSession {
    /// Start `plan` as a session: resolve sites, register every stream
    /// as an in-flight transfer (so GRIS `load` and link sharing see
    /// the co-allocated session, mirroring what `GridFtp::fetch` does
    /// for a single stream), and dispatch the opening blocks into
    /// `flows` under downlink `group`. An empty plan starts already
    /// done with an empty outcome.
    pub fn start(
        flows: &mut FlowSet,
        topo: &mut Topology,
        plan: &StripePlan,
        policy: &CoallocPolicy,
        client: &str,
        group: usize,
    ) -> Result<CoallocSession> {
        Self::start_traced(flows, topo, plan, policy, client, group, TraceHandle::disabled(), 0)
    }

    /// [`Self::start`] with the flight recorder attached: every block
    /// dispatch / steal / failover / retry / completion is recorded
    /// under request id `req` — the opening dispatch included, since
    /// the handle is installed before the first maintenance pass.
    #[allow(clippy::too_many_arguments)]
    pub fn start_traced(
        flows: &mut FlowSet,
        topo: &mut Topology,
        plan: &StripePlan,
        policy: &CoallocPolicy,
        client: &str,
        group: usize,
        trace: TraceHandle,
        req: ReqId,
    ) -> Result<CoallocSession> {
        let mut streams: Vec<Stream> = Vec::with_capacity(plan.assignments.len());
        for a in &plan.assignments {
            let site = match topo.index_of(&a.source.site) {
                Some(i) => i,
                None => bail!("coalloc plan names unknown site {:?}", a.source.site),
            };
            streams.push(Stream {
                site,
                site_name: a.source.site.clone(),
                queue: (a.first_block..a.first_block + a.blocks).collect(),
                current: None,
                blocks_done: 0,
                stolen_done: 0,
                bytes_done: 0.0,
                busy_time: 0.0,
                est_bw: a.source.predicted_bw.max(0.0),
                finished: false,
                failed: false,
                failures: 0,
            });
        }
        for s in &streams {
            topo.begin_transfer(s.site);
        }
        let mut planned_owner: Vec<usize> = vec![0; plan.n_blocks];
        for (s, a) in plan.assignments.iter().enumerate() {
            for b in a.first_block..a.first_block + a.blocks {
                planned_owner[b] = s;
            }
        }
        let mut session = CoallocSession {
            streams,
            plan: plan.clone(),
            policy: policy.clone(),
            client: client.to_string(),
            group,
            flow_to_stream: BTreeMap::new(),
            planned_owner,
            delivered: vec![false; plan.n_blocks],
            retries: vec![0; plan.n_blocks],
            failovers: 0,
            blocks_requeued: 0,
            steals: 0,
            started_at: topo.now,
            finish_at: topo.now,
            min_steal: policy.rebalance_threshold.max(1.0).ceil() as usize,
            err: None,
            done: false,
            trace,
            trace_req: req,
        };
        // The opening maintenance pass: failover check (a fault may
        // already be active) + initial block dispatch.
        session.step(flows, topo);
        Ok(session)
    }

    /// The session's maintenance tick period (simulated seconds) — the
    /// cadence drivers should fire [`Self::step`] at.
    pub fn tick_period(&self) -> f64 {
        self.policy.tick.max(1e-3)
    }

    /// Whether the session reached a terminal state (success or error).
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// One maintenance pass: detect dead/stalled sources and orphan
    /// their work, then hand every idle stream its next block (own
    /// queue first, then steal). Safe to call at any instant — it is
    /// idempotent at a fixed state — and a no-op once done.
    pub fn step(&mut self, flows: &mut FlowSet, topo: &mut Topology) {
        if self.done {
            return;
        }
        if let Err(e) = self.detect_failures(flows, topo) {
            self.abort(flows, topo, e);
            return;
        }
        self.assign_idle(flows, topo);
        if self.streams.iter().all(|s| s.finished || s.failed) {
            self.done = true;
        }
    }

    /// React to a flow completion from the kernel. Returns `false`
    /// (and changes nothing) when the flow is not this session's — the
    /// dispatch test for drivers multiplexing several sessions on one
    /// shared set. Otherwise records the block into the history store,
    /// folds the observed throughput into the stream's bandwidth
    /// estimate, and immediately re-dispatches (steals included) so
    /// throughput is not quantized to the maintenance tick.
    pub fn on_flow_done(
        &mut self,
        flows: &mut FlowSet,
        topo: &mut Topology,
        ftp: &GridFtp,
        c: &Completion,
    ) -> bool {
        let owner = match self.flow_to_stream.remove(&c.flow) {
            Some(o) => o,
            None => return false,
        };
        if self.done {
            return true;
        }
        if let Err(e) = self.record_completion(ftp, owner, c) {
            self.abort(flows, topo, e);
            return true;
        }
        self.step(flows, topo);
        true
    }

    /// Failover detection (see the module docs' state machine): fail
    /// every running stream whose source died or whose in-flight block
    /// timed out. The in-flight block is cancelled, charged one retry
    /// and pushed back; the stream's slot is released; retired
    /// survivors are revived to adopt the orphans. Errors when
    /// failover is disabled, a block exhausts its retry budget, or no
    /// live source remains.
    fn detect_failures(&mut self, flows: &mut FlowSet, topo: &mut Topology) -> Result<()> {
        // Crash → recover (ISSUE 7 grid weather): a failed stream
        // whose source healed rejoins the session while work remains —
        // it re-acquires its transfer slot and runs its own orphan
        // queue (or steals) instead of sitting out the rest of the
        // transfer. A re-crash just fails it over again; the per-block
        // retry budget bounds the flapping. This runs as a pre-pass so
        // a failure detected below already sees every healed peer as a
        // live adopter, whatever the stream order.
        if self.streams.iter().any(|s| !s.queue.is_empty()) {
            for i in 0..self.streams.len() {
                if self.streams[i].failed && topo.site_alive(self.streams[i].site) {
                    self.streams[i].failed = false;
                    topo.begin_transfer(self.streams[i].site);
                }
            }
        }
        for i in 0..self.streams.len() {
            if self.streams[i].finished || self.streams[i].failed {
                continue;
            }
            let dead = !topo.site_alive(self.streams[i].site);
            let stalled = matches!(
                self.streams[i].current,
                Some((_, _, at)) if topo.now - at > self.policy.block_timeout
            );
            if !dead && !stalled {
                continue;
            }
            let reason = if dead { "died" } else { "stalled" };
            let (site_name, orphans, over_budget, retried) = {
                let s = &mut self.streams[i];
                s.failed = true;
                self.failovers += 1;
                let mut orphans = s.queue.len();
                let mut over_budget = None;
                let mut retried = None;
                if let Some((block, fid, _)) = s.current.take() {
                    flows.cancel(fid);
                    self.flow_to_stream.remove(&fid);
                    s.failures += 1;
                    self.retries[block] += 1;
                    orphans += 1;
                    s.queue.push_front(block);
                    retried = Some(block);
                    if self.retries[block] > self.policy.max_block_retries {
                        over_budget = Some(block);
                    }
                }
                topo.end_transfer(s.site);
                self.blocks_requeued += orphans;
                (s.site_name.clone(), orphans, over_budget, retried)
            };
            if self.trace.on() {
                let (req, at, orphaned) = (self.trace_req, topo.now, orphans as u32);
                let name = site_name.clone();
                self.trace.with(|r| {
                    let site = r.intern(&name);
                    if let Some(b) = retried {
                        r.push(at, req, Ev::BlockRetry { site, block: b as u64 });
                    }
                    r.push(at, req, Ev::BlockFailover { site, orphaned });
                });
            }
            if self.policy.max_block_retries == 0 && orphans > 0 {
                // Paper-era behaviour: losing a source with work
                // pending kills the whole transfer.
                bail!(
                    "source {site_name} {reason} mid-transfer and failover is \
                     disabled (max_block_retries = 0)"
                );
            }
            if let Some(block) = over_budget {
                bail!(
                    "block {block} exceeded its retry budget \
                     ({} re-queues) after source {site_name} {reason}",
                    self.policy.max_block_retries
                );
            }
            if orphans > 0 {
                // Revive retired survivors: orphaned blocks must
                // always find a live stream to adopt them.
                for j in 0..self.streams.len() {
                    if self.streams[j].finished && topo.site_alive(self.streams[j].site) {
                        self.streams[j].finished = false;
                        topo.begin_transfer(self.streams[j].site);
                    }
                }
                if !self.streams.iter().any(|s| s.active()) {
                    bail!(
                        "source {site_name} {reason} and no live source remains \
                         to adopt its {orphans} blocks"
                    );
                }
            }
        }
        Ok(())
    }

    /// Hand every idle stream its next block: own queue first, then a
    /// rate-gated steal of the tail half of the largest peer backlog
    /// (the stream must clear one block before the victim could drain
    /// its own backlog, judging by predicted-then-observed rates;
    /// unknown rates on either side permit the steal). *Failed* peers
    /// are always valid victims regardless of backlog size or rates —
    /// their queues are orphans that must move. A stream with nothing
    /// to run and no stealable peer backlog retires and releases its
    /// transfer slot; a gate-blocked stream stays idle and
    /// re-evaluates as estimates update.
    fn assign_idle(&mut self, flows: &mut FlowSet, topo: &mut Topology) {
        for i in 0..self.streams.len() {
            if self.streams[i].current.is_some()
                || self.streams[i].finished
                || self.streams[i].failed
            {
                continue;
            }
            let block = match self.streams[i].queue.pop_front() {
                Some(b) => Some(b),
                None => {
                    let est_i = self.streams[i].est_bw;
                    let victim = (0..self.streams.len())
                        .filter(|&j| {
                            if j == i {
                                return false;
                            }
                            if self.streams[j].failed {
                                return !self.streams[j].queue.is_empty();
                            }
                            if self.streams[j].queue.len() < self.min_steal {
                                return false;
                            }
                            let est_v = self.streams[j].est_bw;
                            est_i <= 0.0
                                || est_v <= 0.0
                                || est_v < self.streams[j].queue.len() as f64 * est_i
                        })
                        .max_by_key(|&j| self.streams[j].queue.len());
                    match victim {
                        Some(v) => {
                            let take = (self.streams[v].queue.len() + 1) / 2;
                            let mut grabbed: Vec<usize> = (0..take)
                                .filter_map(|_| self.streams[v].queue.pop_back())
                                .collect();
                            grabbed.reverse(); // keep ascending offsets
                            self.steals += 1;
                            if self.trace.on() {
                                let (req, at) = (self.trace_req, topo.now);
                                let moved = grabbed.len() as u32;
                                let from_name = self.streams[v].site_name.clone();
                                let to_name = self.streams[i].site_name.clone();
                                self.trace.with(|r| {
                                    let from = r.intern(&from_name);
                                    let to = r.intern(&to_name);
                                    r.push(at, req, Ev::BlockSteal { from, to, blocks: moved });
                                });
                            }
                            let mut it = grabbed.into_iter();
                            let first = it.next();
                            for b in it {
                                self.streams[i].queue.push_back(b);
                            }
                            first
                        }
                        None => {
                            let any_backlog = (0..self.streams.len()).any(|j| {
                                j != i
                                    && if self.streams[j].failed {
                                        !self.streams[j].queue.is_empty()
                                    } else {
                                        self.streams[j].queue.len() >= self.min_steal
                                    }
                            });
                            if !any_backlog {
                                self.streams[i].finished = true;
                                topo.end_transfer(self.streams[i].site);
                            }
                            None
                        }
                    }
                }
            };
            if let Some(b) = block {
                let (_, len) = self.plan.block_range(b);
                // Per-block setup: connection latency + the disk seek
                // (`drdTime`) every ranged read pays; the streaming
                // disk rate itself caps the flow in `FlowSet`.
                let lead = {
                    let sc = &topo.site(self.streams[i].site).cfg;
                    sc.latency + sc.drd_time_ms / 1e3
                };
                let fid = flows.add_in(topo, self.streams[i].site, len, lead, self.group);
                self.flow_to_stream.insert(fid, i);
                self.streams[i].current = Some((b, fid, topo.now));
                if self.trace.on() {
                    let (req, at) = (self.trace_req, topo.now);
                    let name = self.streams[i].site_name.clone();
                    self.trace.with(|r| {
                        let site = r.intern(&name);
                        r.push(at, req, Ev::BlockStart { site, block: b as u64, bytes: len as u64 });
                    });
                }
            }
        }
    }

    /// Instrument one completed block into the history store and fold
    /// the observed throughput into the stream's bandwidth estimate.
    /// Errors if a block lands twice (the exactly-once ledger is
    /// violated).
    fn record_completion(&mut self, ftp: &GridFtp, owner: usize, c: &Completion) -> Result<()> {
        let (block, fid, assigned_at) = match self.streams[owner].current.take() {
            Some(cur) => cur,
            None => return Ok(()),
        };
        debug_assert_eq!(fid, c.flow);
        if self.delivered[block] {
            bail!("integrity violation: block {block} delivered twice");
        }
        self.delivered[block] = true;
        let (_, len) = self.plan.block_range(block);
        let duration = (c.at - assigned_at).max(1e-9);
        if self.trace.on() {
            let (req, at) = (self.trace_req, c.at);
            let name = self.streams[owner].site_name.clone();
            self.trace.with(|r| {
                let site = r.intern(&name);
                r.push(at, req, Ev::BlockFinish { site, block: block as u64, bytes: len as u64 });
            });
        }
        ftp.record(
            self.streams[owner].site,
            TransferRecord {
                at: assigned_at,
                peer: self.client.clone(),
                direction: Direction::Read,
                bytes: len,
                duration,
            },
        );
        let s = &mut self.streams[owner];
        s.blocks_done += 1;
        if self.planned_owner[block] != owner {
            s.stolen_done += 1;
        }
        s.bytes_done += len;
        s.busy_time += duration;
        let observed = len / duration;
        s.est_bw = if s.est_bw > 0.0 {
            0.5 * s.est_bw + 0.5 * observed
        } else {
            observed
        };
        if c.at > self.finish_at {
            self.finish_at = c.at;
        }
        Ok(())
    }

    /// Terminal failure: cancel this session's in-flight flows (their
    /// downlink share returns to the survivors on the shared set),
    /// release every still-active transfer slot, and latch the error.
    fn abort(&mut self, flows: &mut FlowSet, topo: &mut Topology, e: anyhow::Error) {
        for s in &mut self.streams {
            if let Some((_, fid, _)) = s.current.take() {
                flows.cancel(fid);
                self.flow_to_stream.remove(&fid);
            }
        }
        release_active(&self.streams, topo);
        self.err = Some(e);
        self.done = true;
    }

    /// Consume the session and produce its outcome: the latched error,
    /// or the assembled transfer after the final integrity check (the
    /// per-completion ledger rejects duplicates; this rejects holes —
    /// e.g. every source died).
    pub fn outcome(self) -> Result<CoallocOutcome> {
        if let Some(e) = self.err {
            return Err(e);
        }
        if !self.done {
            bail!("coalloc session consumed before it finished");
        }
        let undelivered = self.delivered.iter().filter(|&&d| !d).count();
        if undelivered > 0 {
            bail!(
                "co-allocated transfer lost {undelivered} of {} blocks \
                 (no surviving source adopted them)",
                self.plan.n_blocks
            );
        }
        let bytes: f64 = self.streams.iter().map(|s| s.bytes_done).sum();
        if (bytes - self.plan.total_bytes).abs() > 1.0 {
            bail!(
                "integrity violation: assembled {bytes} bytes != file size {}",
                self.plan.total_bytes
            );
        }
        let duration = (self.finish_at - self.started_at).max(0.0);
        Ok(CoallocOutcome {
            bytes,
            duration,
            started_at: self.started_at,
            aggregate_bandwidth: if duration > 0.0 { bytes / duration } else { 0.0 },
            steals: self.steals,
            failovers: self.failovers,
            blocks_requeued: self.blocks_requeued,
            retries_total: self.retries.iter().sum(),
            retries_peak: self.retries.iter().copied().max().unwrap_or(0),
            streams: self
                .streams
                .iter()
                .map(|s| StreamReport {
                    site: s.site_name.clone(),
                    site_index: s.site,
                    blocks: s.blocks_done,
                    stolen: s.stolen_done,
                    bytes: s.bytes_done,
                    mean_bandwidth: if s.busy_time > 0.0 {
                        s.bytes_done / s.busy_time
                    } else {
                        0.0
                    },
                    failures: s.failures,
                    failed: s.failed,
                })
                .collect(),
        })
    }
}

/// Event budget for [`execute`]: far above any real transfer (ticks +
/// one completion per block), so pathological configs terminate with
/// an error instead of spinning forever.
const MAX_EXECUTE_EVENTS: usize = 4_000_000;

/// Execute `plan` against the live topology, instrumenting every block
/// into the per-site history stores. `client` is the requesting
/// endpoint (the Figure-5 "source" the GRIS publishes per-peer history
/// for). Survives source churn per the module docs' failover state
/// machine; the returned outcome passed the exactly-once integrity
/// check over the assembled byte ranges.
///
/// This is the one-transfer-alone wrapper: it spins up a private
/// [`Engine`] whose `FlowSet` holds only this session's flows and
/// drives the session to a terminal state. Drivers that want several
/// transfers to contend — the open-loop runtime — run
/// [`CoallocSession`] directly on their shared kernel instead.
pub fn execute(
    topo: &mut Topology,
    ftp: &GridFtp,
    client: &str,
    plan: &StripePlan,
    policy: &CoallocPolicy,
) -> Result<CoallocOutcome> {
    let mut eng = Engine::new(FlowSet::new(policy.client_downlink));
    let mut session = CoallocSession::start(&mut eng.flows, topo, plan, policy, client, 0)?;
    let tick = session.tick_period();
    let mut next_tick = topo.now + tick;
    if !session.is_done() {
        eng.schedule_tick(next_tick, 0);
    }
    let mut events = 0usize;
    while !session.is_done() {
        events += 1;
        if events > MAX_EXECUTE_EVENTS {
            session.abort(
                &mut eng.flows,
                topo,
                anyhow::anyhow!("coalloc transfer did not converge within the tick budget"),
            );
            break;
        }
        match eng.next(topo) {
            Some(Signal::FlowDone(c)) => {
                session.on_flow_done(&mut eng.flows, topo, ftp, &c);
            }
            Some(Signal::Tick { .. }) => {
                session.step(&mut eng.flows, topo);
                if !session.is_done() {
                    next_tick += tick;
                    eng.schedule_tick(next_tick, 0);
                }
            }
            Some(Signal::Arrival { .. }) | Some(Signal::Query { .. }) => {
                unreachable!("the private coalloc engine schedules no arrivals or queries")
            }
            None => {
                // No scheduled events and no flow progress — a stalled
                // set the maintenance tick stopped watching.
                session.abort(
                    &mut eng.flows,
                    topo,
                    anyhow::anyhow!("coalloc transfer did not converge within the tick budget"),
                );
                break;
            }
        }
    }
    session.outcome()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coalloc::planner::{plan_stripes, StripeSource};
    use crate::config::GridConfig;
    use crate::simnet::FaultKind;

    fn flat_grid(n: usize, bw: f64) -> (GridConfig, Topology, GridFtp) {
        let mut cfg = GridConfig::generate(n, 17);
        for s in &mut cfg.sites {
            s.wan_bandwidth = bw;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
            s.disk_rate = 1e9;
            s.drd_time_ms = 0.0;
        }
        let topo = Topology::build(&cfg);
        let ftp = GridFtp::new(&topo, 32);
        (cfg, topo, ftp)
    }

    fn sources(cfg: &GridConfig, bws: &[f64]) -> Vec<StripeSource> {
        bws.iter()
            .enumerate()
            .map(|(i, &bw)| StripeSource {
                site: cfg.sites[i].name.clone(),
                url: format!("gsiftp://{}/f", cfg.sites[i].name),
                predicted_bw: bw,
            })
            .collect()
    }

    #[test]
    fn delivers_every_byte_and_instruments_history() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 3,
            tick: 1.0,
            ..Default::default()
        };
        let srcs = sources(&cfg, &[1e6, 1e6, 1e6]);
        let plan = plan_stripes(&srcs, 60e6, &policy);
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        assert!((out.bytes - 60e6).abs() < 1.0);
        let delivered: usize = out.streams.iter().map(|s| s.blocks).sum();
        assert_eq!(delivered, plan.n_blocks);
        assert_eq!(out.failovers, 0);
        assert_eq!(out.blocks_requeued, 0);
        assert_eq!(out.retries_peak, 0);
        // Instrumentation: every block is a read record under the
        // client peer, in the same store the GRIS providers read.
        for s in &out.streams {
            let h = ftp.history(s.site_index);
            let h = h.read().unwrap();
            assert_eq!(h.rd.count as usize, s.blocks);
            assert_eq!(
                h.source("client").map(|sh| sh.stats.count).unwrap_or(0) as usize,
                s.blocks
            );
        }
        // All streams registered and released their transfer slots.
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn parallel_streams_beat_one_stream() {
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 4,
            tick: 1.0,
            ..Default::default()
        };
        let (cfg, mut topo, ftp) = flat_grid(4, 1e6);
        let srcs = sources(&cfg, &[1e6, 1e6, 1e6, 1e6]);
        let plan = plan_stripes(&srcs, 80e6, &policy);
        let par = execute(&mut topo, &ftp, "c", &plan, &policy).unwrap();

        let (cfg1, mut topo1, ftp1) = flat_grid(4, 1e6);
        let one = CoallocPolicy { max_streams: 1, ..policy.clone() };
        let plan1 = plan_stripes(&sources(&cfg1, &[1e6]), 80e6, &one);
        let solo = execute(&mut topo1, &ftp1, "c", &plan1, &one).unwrap();
        assert!(
            par.duration < solo.duration / 2.0,
            "par {:.0}s !<< solo {:.0}s",
            par.duration,
            solo.duration
        );
    }

    #[test]
    fn slow_stream_sheds_blocks_to_fast_peers() {
        let (mut cfg, _, _) = flat_grid(3, 1e6);
        // Site 0 is actually 10x slower than the plan believes.
        cfg.sites[0].wan_bandwidth = 0.1e6;
        let mut topo = Topology::build(&cfg);
        let ftp = GridFtp::new(&topo, 32);
        let policy = CoallocPolicy {
            block_size: 2e6,
            max_streams: 3,
            tick: 1.0,
            rebalance_threshold: 2.0,
            ..Default::default()
        };
        // Plan assumes all three are equally fast.
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6, 1e6]), 60e6, &policy);
        let out = execute(&mut topo, &ftp, "c", &plan, &policy).unwrap();
        assert!(out.steals > 0, "expected work stealing");
        let slow = &out.streams[0];
        let fast_blocks: usize =
            out.streams[1..].iter().map(|s| s.blocks).sum();
        assert!(
            slow.blocks < fast_blocks / 2,
            "slow did {} of {} blocks",
            slow.blocks,
            slow.blocks + fast_blocks
        );
        let stolen_total: usize = out.streams.iter().map(|s| s.stolen).sum();
        assert!(stolen_total > 0);
        // Rebalancing keeps the makespan near the fast links' pace:
        // without stealing the slow stream alone would need ~200s for
        // its 20 MB third at a 1/2-shared 0.1e6 B/s link.
        assert!(out.duration < 150.0, "duration {:.0}s", out.duration);
    }

    #[test]
    fn replica_death_fails_over_to_survivors() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 3,
            tick: 1.0,
            max_block_retries: 3,
            ..Default::default()
        };
        let srcs = sources(&cfg, &[1e6, 1e6, 1e6]);
        let plan = plan_stripes(&srcs, 60e6, &policy);
        // Site 0 dies a third of the way into the transfer (~20s of
        // the ~60s steady-state makespan over 3 × 1 MB/s links).
        topo.schedule_fault(0, 20.0, FaultKind::ReplicaDeath);
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        // Every byte still arrives, exactly once.
        assert!((out.bytes - 60e6).abs() < 1.0);
        let delivered: usize = out.streams.iter().map(|s| s.blocks).sum();
        assert_eq!(delivered, plan.n_blocks);
        // The failover surfaced in the counters.
        assert_eq!(out.failovers, 1);
        assert!(out.blocks_requeued > 0);
        assert_eq!(out.retries_total, 1, "one in-flight block was cancelled");
        assert!(out.retries_peak <= policy.max_block_retries);
        let dead = &out.streams[0];
        assert!(dead.failed);
        assert_eq!(dead.failures, 1);
        // Survivors adopted the dead stream's share.
        assert!(dead.blocks < plan.assignments[0].blocks);
        let survivor_blocks: usize =
            out.streams[1..].iter().map(|s| s.blocks).sum();
        assert_eq!(dead.blocks + survivor_blocks, plan.n_blocks);
        // Slot accounting stays balanced through the failover.
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn death_without_failover_fails_fast() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 3,
            tick: 1.0,
            max_block_retries: 0,
            ..Default::default()
        };
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6, 1e6]), 60e6, &policy);
        topo.schedule_fault(1, 20.0, FaultKind::ReplicaDeath);
        let err = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap_err();
        assert!(
            format!("{err:#}").contains("failover is disabled"),
            "unexpected error: {err:#}"
        );
        // Error path released every slot.
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn all_sources_dying_is_an_error_not_a_hang() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 2,
            tick: 1.0,
            max_block_retries: 5,
            ..Default::default()
        };
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6]), 40e6, &policy);
        topo.schedule_fault(0, 5.0, FaultKind::ReplicaDeath);
        topo.schedule_fault(1, 5.0, FaultKind::ReplicaDeath);
        let err = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("no live source") || msg.contains("lost"),
            "unexpected error: {msg}"
        );
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn healed_source_rejoins_and_delivers_again() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 2,
            tick: 1.0,
            max_block_retries: 3,
            ..Default::default()
        };
        // 10 × 4 MB blocks per stream at ~4 s/block on a 1 MB/s link.
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6]), 80e6, &policy);
        // Site 0 crashes early and recovers mid-transfer — long before
        // the survivor (busy with its own 40 s stripe) would steal the
        // whole orphan queue.
        topo.schedule_fault_for(0, 6.0, 20.0, FaultKind::ReplicaDeath);
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        assert!((out.bytes - 80e6).abs() < 1.0);
        let delivered: usize = out.streams.iter().map(|s| s.blocks).sum();
        assert_eq!(delivered, plan.n_blocks);
        assert_eq!(out.failovers, 1);
        let healed = &out.streams[0];
        assert!(!healed.failed, "revived stream must not end in the failed state");
        assert_eq!(healed.failures, 1, "the crash cancelled its in-flight block");
        // It rejoined and moved real data after the heal: one block
        // pre-crash, so ≥ 2 proves post-heal deliveries.
        assert!(healed.blocks >= 2, "healed stream delivered only {}", healed.blocks);
        // Slot accounting balanced through fail → revive → finish.
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn flapping_source_exhausts_the_block_retry_budget() {
        // Crash/heal cycles re-fail the same stream; each cycle
        // charges the in-flight block a retry, and the budget turns
        // unbounded flapping into a clean error instead of livelock.
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 2,
            tick: 1.0,
            max_block_retries: 2,
            ..Default::default()
        };
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6]), 80e6, &policy);
        // Staggered flaps — site 0 down on [2,4),[6,8),…, site 1 on
        // [4,6),[8,10),… — so a live adopter always exists (the
        // no-live-source bail never fires) but no 2 s up-window fits a
        // 4 s block. Each crash cancels the stream's front block and
        // charges it a retry; site 0's first block blows the budget of
        // 2 on its third cancellation at t=10.
        for k in 0..40 {
            topo.schedule_fault_for(0, 2.0 + 4.0 * k as f64, 2.0, FaultKind::ReplicaDeath);
            topo.schedule_fault_for(1, 4.0 + 4.0 * k as f64, 2.0, FaultKind::ReplicaDeath);
        }
        let err = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("retry budget"), "unexpected error: {msg}");
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn stalled_source_times_out_and_sheds_its_blocks() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 3,
            tick: 1.0,
            max_block_retries: 2,
            block_timeout: 30.0,
            ..Default::default()
        };
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6, 1e6]), 60e6, &policy);
        // Site 2's link collapses to 0.1% — not dead on the control
        // channel, but its 4 s blocks now take ~4000 s: a stall.
        topo.schedule_fault(2, 10.0, FaultKind::LinkDegrade { factor: 0.001 });
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        assert!((out.bytes - 60e6).abs() < 1.0);
        assert_eq!(out.failovers, 1);
        let stalled = &out.streams[2];
        assert!(stalled.failed);
        // The healthy pair absorbed the remainder within their pace
        // (not the stalled link's ~4000 s per block).
        assert!(out.duration < 200.0, "duration {:.0}s", out.duration);
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn outcome_records_metrics() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 2,
            tick: 1.0,
            ..Default::default()
        };
        let srcs = sources(&cfg, &[1e6, 1e6]);
        let plan = plan_stripes(&srcs, 16e6, &policy);
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        let m = crate::metrics::Metrics::new();
        out.record_metrics(&m);
        assert_eq!(m.counter("coalloc.transfers").get(), 1);
        assert_eq!(m.counter("coalloc.bytes").get(), out.bytes as u64);
        assert_eq!(m.counter("coalloc.failovers").get(), 0);
        assert_eq!(m.counter("coalloc.blocks_requeued").get(), 0);
        assert_eq!(m.histogram("coalloc.completion_ns").count(), 1);
        let per_site: u64 = out
            .streams
            .iter()
            .map(|s| m.counter(&format!("coalloc.bytes.{}", s.site)).get())
            .sum();
        assert_eq!(per_site, out.bytes as u64);
        let stolen: u64 = out.streams.iter().map(|s| s.stolen as u64).sum();
        assert_eq!(m.counter("coalloc.blocks_stolen").get(), stolen);
    }

    #[test]
    fn failover_counters_reach_metrics() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 3,
            tick: 1.0,
            ..Default::default()
        };
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6, 1e6]), 60e6, &policy);
        topo.schedule_fault(0, 20.0, FaultKind::ReplicaDeath);
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        let m = crate::metrics::Metrics::new();
        out.record_metrics(&m);
        assert_eq!(m.counter("coalloc.failovers").get(), 1);
        assert!(m.counter("coalloc.blocks_requeued").get() > 0);
        let dead_site = &out.streams[0].site;
        assert!(m.counter(&format!("coalloc.failures.{dead_site}")).get() >= 1);
    }

    #[test]
    fn two_sessions_coexist_on_one_shared_kernel() {
        // Two co-allocated transfers from the same client population,
        // driven concurrently on ONE engine + one grid-wide FlowSet:
        // each session gets its own downlink group, both contend on
        // the shared site links, and both deliver every byte exactly
        // once. A serial baseline (one `execute` after the other on a
        // fresh grid) shows the contention: overlapping sessions see
        // slower links than transfers that run alone.
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 3,
            tick: 1.0,
            ..Default::default()
        };
        let srcs = sources(&cfg, &[1e6, 1e6, 1e6]);
        let plan_a = plan_stripes(&srcs, 36e6, &policy);
        let plan_b = plan_stripes(&srcs, 36e6, &policy);

        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        let ga = eng.flows.add_group(policy.client_downlink);
        let gb = eng.flows.add_group(policy.client_downlink);
        let mut sa =
            CoallocSession::start(&mut eng.flows, &mut topo, &plan_a, &policy, "a", ga).unwrap();
        let mut sb =
            CoallocSession::start(&mut eng.flows, &mut topo, &plan_b, &policy, "b", gb).unwrap();
        let tick = sa.tick_period();
        let mut next_tick = topo.now + tick;
        eng.schedule_tick(next_tick, 0);
        let mut guard = 0;
        while !(sa.is_done() && sb.is_done()) {
            guard += 1;
            assert!(guard < 100_000, "shared-kernel run did not converge");
            match eng.next(&mut topo) {
                Some(Signal::FlowDone(c)) => {
                    // Exactly one session owns each flow.
                    let in_a = sa.on_flow_done(&mut eng.flows, &mut topo, &ftp, &c);
                    if !in_a {
                        assert!(sb.on_flow_done(&mut eng.flows, &mut topo, &ftp, &c));
                    }
                }
                Some(Signal::Tick { .. }) => {
                    sa.step(&mut eng.flows, &mut topo);
                    sb.step(&mut eng.flows, &mut topo);
                    if !(sa.is_done() && sb.is_done()) {
                        next_tick += tick;
                        eng.schedule_tick(next_tick, 0);
                    }
                }
                other => panic!("unexpected signal {other:?}"),
            }
        }
        let oa = sa.outcome().unwrap();
        let ob = sb.outcome().unwrap();
        assert!((oa.bytes - 36e6).abs() < 1.0);
        assert!((ob.bytes - 36e6).abs() < 1.0);
        // Contention check: run the same two transfers serially on a
        // fresh grid — each alone on the links, so each is faster than
        // the overlapped runs.
        let (_, mut topo2, ftp2) = flat_grid(3, 1e6);
        let solo_a = execute(&mut topo2, &ftp2, "a", &plan_a, &policy).unwrap();
        let solo_b = execute(&mut topo2, &ftp2, "b", &plan_b, &policy).unwrap();
        assert!(
            oa.duration > solo_a.duration * 1.2 && ob.duration > solo_b.duration * 1.2,
            "overlap {:.1}s/{:.1}s !> solo {:.1}s/{:.1}s",
            oa.duration,
            ob.duration,
            solo_a.duration,
            solo_b.duration
        );
        // Slot accounting stays balanced across both sessions.
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn traced_session_records_block_lifecycle() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 2,
            tick: 1.0,
            ..Default::default()
        };
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6]), 16e6, &policy);
        let trace = TraceHandle::new(1 << 12);
        let mut eng = Engine::new(FlowSet::new(policy.client_downlink));
        let mut session = CoallocSession::start_traced(
            &mut eng.flows,
            &mut topo,
            &plan,
            &policy,
            "c",
            0,
            trace.clone(),
            7,
        )
        .unwrap();
        let tick = session.tick_period();
        let mut next_tick = topo.now + tick;
        eng.schedule_tick(next_tick, 0);
        let mut guard = 0;
        while !session.is_done() {
            guard += 1;
            assert!(guard < 100_000, "traced run did not converge");
            match eng.next(&mut topo) {
                Some(Signal::FlowDone(c)) => {
                    session.on_flow_done(&mut eng.flows, &mut topo, &ftp, &c);
                }
                Some(Signal::Tick { .. }) => {
                    session.step(&mut eng.flows, &mut topo);
                    if !session.is_done() {
                        next_tick += tick;
                        eng.schedule_tick(next_tick, 0);
                    }
                }
                other => panic!("unexpected signal {other:?}"),
            }
        }
        let out = session.outcome().unwrap();
        let (starts, finishes) = trace
            .read(|r| {
                let evs = r.events();
                (
                    evs.iter().filter(|e| matches!(e.ev, Ev::BlockStart { .. })).count(),
                    evs.iter().filter(|e| matches!(e.ev, Ev::BlockFinish { .. })).count(),
                )
            })
            .unwrap();
        // Every block starts exactly once per attempt and finishes once.
        assert_eq!(finishes, plan.n_blocks);
        assert_eq!(starts, plan.n_blocks + out.retries_total);
        // All events are filed under the session's request id.
        assert!(trace.read(|r| r.events().iter().all(|e| e.req == 7)).unwrap());
    }

    #[test]
    fn unknown_site_is_an_error() {
        let (_, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy::default();
        let plan = plan_stripes(
            &[StripeSource { site: "ghost".into(), url: "u".into(), predicted_bw: 1e6 }],
            1e6,
            &policy,
        );
        assert!(execute(&mut topo, &ftp, "c", &plan, &policy).is_err());
    }

    #[test]
    fn empty_plan_is_a_noop() {
        let (_, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy::default();
        let plan = plan_stripes(&[], 0.0, &policy);
        let out = execute(&mut topo, &ftp, "c", &plan, &policy).unwrap();
        assert_eq!(out.bytes, 0.0);
        assert_eq!(out.duration, 0.0);
    }
}
