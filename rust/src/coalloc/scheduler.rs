//! The chunk scheduler: executes a [`StripePlan`] over the simulated
//! grid as one co-allocated transfer.
//!
//! Each assignment becomes a *stream* pinned to one replica site. A
//! stream pulls blocks from its own queue; the streams' current blocks
//! advance together through [`simnet::FlowSet`], so same-site streams
//! split that link and all streams share the client downlink. When a
//! stream drains its queue it *steals* the tail half of the largest
//! backlog among its peers (policy `rebalance_threshold` gates the
//! steal) — a slowing source sheds blocks to faster ones without any
//! central re-planning.
//!
//! Every completed block is instrumented as a [`TransferRecord`] into
//! the source site's `HistoryStore` via [`GridFtp::record`] — the same
//! store the site's GRIS providers publish from — so co-allocated
//! traffic feeds the selection history exactly like single-source
//! fetches do (paper §3.2).

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::config::CoallocPolicy;
use crate::gridftp::history::{Direction, TransferRecord};
use crate::gridftp::GridFtp;
use crate::simnet::{FlowSet, Topology};

use super::planner::StripePlan;

/// Per-stream outcome.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub site: String,
    pub site_index: usize,
    /// Blocks this stream delivered (own + stolen).
    pub blocks: usize,
    /// Blocks it delivered that were stolen from peers.
    pub stolen: usize,
    /// Bytes delivered.
    pub bytes: f64,
    /// Mean delivered bandwidth over the stream's busy time (bytes/s).
    pub mean_bandwidth: f64,
}

/// Outcome of one co-allocated transfer.
#[derive(Debug, Clone)]
pub struct CoallocOutcome {
    pub bytes: f64,
    /// Wall (simulated) time from start to last block completion.
    pub duration: f64,
    pub started_at: f64,
    /// bytes / duration.
    pub aggregate_bandwidth: f64,
    /// Total steal events (a steal moves ≥1 block between queues).
    pub steals: usize,
    pub streams: Vec<StreamReport>,
}

impl CoallocOutcome {
    /// Surface this outcome's counters through a [`Metrics`] registry
    /// (ROADMAP open item): transfer/steal counts, blocks stolen,
    /// per-source bytes, and the completion time as a histogram sample.
    /// Simulated seconds are recorded as nanoseconds so the existing
    /// histogram quantile machinery applies unchanged.
    pub fn record_metrics(&self, m: &crate::metrics::Metrics) {
        m.counter("coalloc.transfers").inc();
        m.counter("coalloc.steal_events").add(self.steals as u64);
        m.counter("coalloc.bytes").add(self.bytes as u64);
        m.histogram("coalloc.completion_ns")
            .observe_ns((self.duration * 1e9) as u64);
        for s in &self.streams {
            m.counter("coalloc.blocks_stolen").add(s.stolen as u64);
            m.counter(&format!("coalloc.bytes.{}", s.site)).add(s.bytes as u64);
            m.counter(&format!("coalloc.blocks.{}", s.site)).add(s.blocks as u64);
        }
    }
}

struct Stream {
    site: usize,
    site_name: String,
    queue: VecDeque<usize>,
    /// (block id, flow id, assigned sim time) of the block in flight.
    current: Option<(usize, usize, f64)>,
    blocks_done: usize,
    stolen_done: usize,
    bytes_done: f64,
    busy_time: f64,
    /// Running bandwidth estimate: the planner's prediction, folded
    /// with observed per-block throughput (EWMA). 0 = unknown.
    est_bw: f64,
    finished: bool,
}

/// Hand every idle stream its next block: own queue first, then a
/// rate-gated steal of the tail half of the largest peer backlog (the
/// stream must clear one block before the victim could drain its own
/// backlog, judging by predicted-then-observed rates; unknown rates on
/// either side permit the steal). A stream with nothing to run and no
/// stealable peer backlog retires and releases its transfer slot; a
/// gate-blocked stream stays idle and re-evaluates as estimates update.
fn assign_idle(
    streams: &mut [Stream],
    topo: &mut Topology,
    flows: &mut FlowSet,
    flow_owner: &mut Vec<usize>,
    steals: &mut usize,
    plan: &StripePlan,
    min_steal: usize,
) {
    for i in 0..streams.len() {
        if streams[i].current.is_some() || streams[i].finished {
            continue;
        }
        let block = match streams[i].queue.pop_front() {
            Some(b) => Some(b),
            None => {
                let est_i = streams[i].est_bw;
                let victim = (0..streams.len())
                    .filter(|&j| {
                        if j == i || streams[j].queue.len() < min_steal {
                            return false;
                        }
                        let est_v = streams[j].est_bw;
                        est_i <= 0.0
                            || est_v <= 0.0
                            || est_v < streams[j].queue.len() as f64 * est_i
                    })
                    .max_by_key(|&j| streams[j].queue.len());
                match victim {
                    Some(v) => {
                        let take = (streams[v].queue.len() + 1) / 2;
                        let mut grabbed: Vec<usize> = (0..take)
                            .filter_map(|_| streams[v].queue.pop_back())
                            .collect();
                        grabbed.reverse(); // keep ascending offsets
                        *steals += 1;
                        let mut it = grabbed.into_iter();
                        let first = it.next();
                        for b in it {
                            streams[i].queue.push_back(b);
                        }
                        first
                    }
                    None => {
                        let any_backlog = (0..streams.len())
                            .any(|j| j != i && streams[j].queue.len() >= min_steal);
                        if !any_backlog {
                            streams[i].finished = true;
                            topo.end_transfer(streams[i].site);
                        }
                        None
                    }
                }
            }
        };
        if let Some(b) = block {
            let (_, len) = plan.block_range(b);
            // Per-block setup: connection latency + the disk seek
            // (`drdTime`) every ranged read pays; the streaming disk
            // rate itself caps the flow in `FlowSet`.
            let lead = {
                let sc = &topo.site(streams[i].site).cfg;
                sc.latency + sc.drd_time_ms / 1e3
            };
            let fid = flows.add(topo, streams[i].site, len, lead);
            flow_owner.push(i);
            streams[i].current = Some((b, fid, topo.now));
        }
    }
}

/// Instrument completed blocks into the history stores and fold the
/// observed throughput into each stream's bandwidth estimate.
#[allow(clippy::too_many_arguments)]
fn record_completions(
    completions: Vec<crate::simnet::Completion>,
    streams: &mut [Stream],
    flow_owner: &[usize],
    planned_owner: &[usize],
    plan: &StripePlan,
    ftp: &GridFtp,
    client: &str,
    finish_at: &mut f64,
) {
    for c in completions {
        let owner = flow_owner[c.flow];
        let s = &mut streams[owner];
        let (block, fid, assigned_at) = match s.current.take() {
            Some(cur) => cur,
            None => continue,
        };
        debug_assert_eq!(fid, c.flow);
        let (_, len) = plan.block_range(block);
        let duration = (c.at - assigned_at).max(1e-9);
        ftp.record(
            s.site,
            TransferRecord {
                at: assigned_at,
                peer: client.to_string(),
                direction: Direction::Read,
                bytes: len,
                duration,
            },
        );
        s.blocks_done += 1;
        if planned_owner[block] != owner {
            s.stolen_done += 1;
        }
        s.bytes_done += len;
        s.busy_time += duration;
        let observed = len / duration;
        s.est_bw = if s.est_bw > 0.0 {
            0.5 * s.est_bw + 0.5 * observed
        } else {
            observed
        };
        if c.at > *finish_at {
            *finish_at = c.at;
        }
    }
}

/// Execute `plan` against the live topology, instrumenting every block
/// into the per-site history stores. `client` is the requesting
/// endpoint (the Figure-5 "source" the GRIS publishes per-peer history
/// for).
pub fn execute(
    topo: &mut Topology,
    ftp: &GridFtp,
    client: &str,
    plan: &StripePlan,
    policy: &CoallocPolicy,
) -> Result<CoallocOutcome> {
    let started_at = topo.now;
    if plan.n_blocks == 0 || plan.assignments.is_empty() {
        return Ok(CoallocOutcome {
            bytes: 0.0,
            duration: 0.0,
            started_at,
            aggregate_bandwidth: 0.0,
            steals: 0,
            streams: Vec::new(),
        });
    }

    let mut streams: Vec<Stream> = Vec::with_capacity(plan.assignments.len());
    for a in &plan.assignments {
        let site = match topo.index_of(&a.source.site) {
            Some(i) => i,
            None => bail!("coalloc plan names unknown site {:?}", a.source.site),
        };
        streams.push(Stream {
            site,
            site_name: a.source.site.clone(),
            queue: (a.first_block..a.first_block + a.blocks).collect(),
            current: None,
            blocks_done: 0,
            stolen_done: 0,
            bytes_done: 0.0,
            busy_time: 0.0,
            est_bw: a.source.predicted_bw.max(0.0),
            finished: false,
        });
    }

    // Register every stream as an in-flight transfer so GRIS `load`
    // and link sharing see the co-allocated session, mirroring what
    // `GridFtp::fetch` does for a single stream.
    for s in &streams {
        topo.begin_transfer(s.site);
    }

    let mut flows = FlowSet::new(policy.client_downlink);
    // flow id → stream index (flows are append-only within the set).
    let mut flow_owner: Vec<usize> = Vec::new();
    // block id → the stream originally assigned it by the planner, so
    // a delivery counts as "stolen" exactly when someone else's block
    // lands (even after multi-hop or steal-back churn).
    let mut planned_owner: Vec<usize> = vec![0; plan.n_blocks];
    for (s, a) in plan.assignments.iter().enumerate() {
        for b in a.first_block..a.first_block + a.blocks {
            planned_owner[b] = s;
        }
    }
    let mut steals = 0usize;
    let mut finish_at = started_at;
    let min_steal = policy.rebalance_threshold.max(1.0).ceil() as usize;
    let tick = policy.tick.max(1e-3);
    // Hard cap: bandwidth is floored at 1 B/s, so pathological configs
    // terminate with an error instead of spinning forever.
    let max_ticks = 2_000_000usize;

    for _ in 0..max_ticks {
        // 1. Hand idle streams work: own queue first, then steal.
        assign_idle(&mut streams, topo, &mut flows, &mut flow_owner, &mut steals, plan, min_steal);

        if streams.iter().all(|s| s.finished) {
            break;
        }

        // 2/3. Advance one tick, re-dispatching freed streams at every
        // completion instant (steal decisions included), so per-stream
        // throughput is not quantized to one block per tick.
        let mut tick_left = tick;
        while tick_left > 1e-12 {
            let (used, completions) = flows.advance_some(topo, tick_left);
            tick_left -= used;
            if completions.is_empty() {
                break;
            }
            record_completions(
                completions,
                &mut streams,
                &flow_owner,
                &planned_owner,
                plan,
                ftp,
                client,
                &mut finish_at,
            );
            if tick_left > 1e-12 {
                assign_idle(
                    &mut streams,
                    topo,
                    &mut flows,
                    &mut flow_owner,
                    &mut steals,
                    plan,
                    min_steal,
                );
            }
        }
    }

    if !streams.iter().all(|s| s.finished) {
        // Release whatever is still registered before failing.
        for s in &streams {
            if !s.finished {
                topo.end_transfer(s.site);
            }
        }
        bail!("coalloc transfer did not converge within the tick budget");
    }

    let bytes: f64 = streams.iter().map(|s| s.bytes_done).sum();
    let duration = (finish_at - started_at).max(0.0);
    Ok(CoallocOutcome {
        bytes,
        duration,
        started_at,
        aggregate_bandwidth: if duration > 0.0 { bytes / duration } else { 0.0 },
        steals,
        streams: streams
            .iter()
            .map(|s| StreamReport {
                site: s.site_name.clone(),
                site_index: s.site,
                blocks: s.blocks_done,
                stolen: s.stolen_done,
                bytes: s.bytes_done,
                mean_bandwidth: if s.busy_time > 0.0 {
                    s.bytes_done / s.busy_time
                } else {
                    0.0
                },
            })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coalloc::planner::{plan_stripes, StripeSource};
    use crate::config::GridConfig;

    fn flat_grid(n: usize, bw: f64) -> (GridConfig, Topology, GridFtp) {
        let mut cfg = GridConfig::generate(n, 17);
        for s in &mut cfg.sites {
            s.wan_bandwidth = bw;
            s.diurnal_amp = 0.0;
            s.noise_frac = 0.0;
            s.congestion_prob = 0.0;
            s.ar_coeff = 0.0;
            s.latency = 0.0;
            s.disk_rate = 1e9;
            s.drd_time_ms = 0.0;
        }
        let topo = Topology::build(&cfg);
        let ftp = GridFtp::new(&topo, 32);
        (cfg, topo, ftp)
    }

    fn sources(cfg: &GridConfig, bws: &[f64]) -> Vec<StripeSource> {
        bws.iter()
            .enumerate()
            .map(|(i, &bw)| StripeSource {
                site: cfg.sites[i].name.clone(),
                url: format!("gsiftp://{}/f", cfg.sites[i].name),
                predicted_bw: bw,
            })
            .collect()
    }

    #[test]
    fn delivers_every_byte_and_instruments_history() {
        let (cfg, mut topo, ftp) = flat_grid(3, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 3,
            tick: 1.0,
            ..Default::default()
        };
        let srcs = sources(&cfg, &[1e6, 1e6, 1e6]);
        let plan = plan_stripes(&srcs, 60e6, &policy);
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        assert!((out.bytes - 60e6).abs() < 1.0);
        let delivered: usize = out.streams.iter().map(|s| s.blocks).sum();
        assert_eq!(delivered, plan.n_blocks);
        // Instrumentation: every block is a read record under the
        // client peer, in the same store the GRIS providers read.
        for s in &out.streams {
            let h = ftp.history(s.site_index);
            let h = h.read().unwrap();
            assert_eq!(h.rd.count as usize, s.blocks);
            assert_eq!(
                h.source("client").map(|sh| sh.stats.count).unwrap_or(0) as usize,
                s.blocks
            );
        }
        // All streams registered and released their transfer slots.
        for i in 0..topo.len() {
            assert_eq!(topo.site(i).active_transfers, 0);
        }
    }

    #[test]
    fn parallel_streams_beat_one_stream() {
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 4,
            tick: 1.0,
            ..Default::default()
        };
        let (cfg, mut topo, ftp) = flat_grid(4, 1e6);
        let srcs = sources(&cfg, &[1e6, 1e6, 1e6, 1e6]);
        let plan = plan_stripes(&srcs, 80e6, &policy);
        let par = execute(&mut topo, &ftp, "c", &plan, &policy).unwrap();

        let (cfg1, mut topo1, ftp1) = flat_grid(4, 1e6);
        let one = CoallocPolicy { max_streams: 1, ..policy.clone() };
        let plan1 = plan_stripes(&sources(&cfg1, &[1e6]), 80e6, &one);
        let solo = execute(&mut topo1, &ftp1, "c", &plan1, &one).unwrap();
        assert!(
            par.duration < solo.duration / 2.0,
            "par {:.0}s !<< solo {:.0}s",
            par.duration,
            solo.duration
        );
    }

    #[test]
    fn slow_stream_sheds_blocks_to_fast_peers() {
        let (mut cfg, _, _) = flat_grid(3, 1e6);
        // Site 0 is actually 10x slower than the plan believes.
        cfg.sites[0].wan_bandwidth = 0.1e6;
        let mut topo = Topology::build(&cfg);
        let ftp = GridFtp::new(&topo, 32);
        let policy = CoallocPolicy {
            block_size: 2e6,
            max_streams: 3,
            tick: 1.0,
            rebalance_threshold: 2.0,
            ..Default::default()
        };
        // Plan assumes all three are equally fast.
        let plan = plan_stripes(&sources(&cfg, &[1e6, 1e6, 1e6]), 60e6, &policy);
        let out = execute(&mut topo, &ftp, "c", &plan, &policy).unwrap();
        assert!(out.steals > 0, "expected work stealing");
        let slow = &out.streams[0];
        let fast_blocks: usize =
            out.streams[1..].iter().map(|s| s.blocks).sum();
        assert!(
            slow.blocks < fast_blocks / 2,
            "slow did {} of {} blocks",
            slow.blocks,
            slow.blocks + fast_blocks
        );
        let stolen_total: usize = out.streams.iter().map(|s| s.stolen).sum();
        assert!(stolen_total > 0);
        // Rebalancing keeps the makespan near the fast links' pace:
        // without stealing the slow stream alone would need ~200s for
        // its 20 MB third at a 1/2-shared 0.1e6 B/s link.
        assert!(out.duration < 150.0, "duration {:.0}s", out.duration);
    }

    #[test]
    fn outcome_records_metrics() {
        let (cfg, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy {
            block_size: 4e6,
            max_streams: 2,
            tick: 1.0,
            ..Default::default()
        };
        let srcs = sources(&cfg, &[1e6, 1e6]);
        let plan = plan_stripes(&srcs, 16e6, &policy);
        let out = execute(&mut topo, &ftp, "client", &plan, &policy).unwrap();
        let m = crate::metrics::Metrics::new();
        out.record_metrics(&m);
        assert_eq!(m.counter("coalloc.transfers").get(), 1);
        assert_eq!(m.counter("coalloc.bytes").get(), out.bytes as u64);
        assert_eq!(m.histogram("coalloc.completion_ns").count(), 1);
        let per_site: u64 = out
            .streams
            .iter()
            .map(|s| m.counter(&format!("coalloc.bytes.{}", s.site)).get())
            .sum();
        assert_eq!(per_site, out.bytes as u64);
        let stolen: u64 = out.streams.iter().map(|s| s.stolen as u64).sum();
        assert_eq!(m.counter("coalloc.blocks_stolen").get(), stolen);
    }

    #[test]
    fn unknown_site_is_an_error() {
        let (_, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy::default();
        let plan = plan_stripes(
            &[StripeSource { site: "ghost".into(), url: "u".into(), predicted_bw: 1e6 }],
            1e6,
            &policy,
        );
        assert!(execute(&mut topo, &ftp, "c", &plan, &policy).is_err());
    }

    #[test]
    fn empty_plan_is_a_noop() {
        let (_, mut topo, ftp) = flat_grid(2, 1e6);
        let policy = CoallocPolicy::default();
        let plan = plan_stripes(&[], 0.0, &policy);
        let out = execute(&mut topo, &ftp, "c", &plan, &policy).unwrap();
        assert_eq!(out.bytes, 0.0);
        assert_eq!(out.duration, 0.0);
    }
}
