//! Co-allocated (striped) transfers — downloading one logical file from
//! several replicas at once.
//!
//! The paper's broker picks a *single* best replica; its future-work
//! discussion and the companion GridFTP transport work (Allcock et al.,
//! cs/0103022) point at parallel transfers that pull disjoint byte
//! ranges of the same file from multiple servers, sized by the same
//! dynamic bandwidth information the selection service already
//! collects. This subsystem implements that Access-phase strategy:
//!
//! * [`planner`] — turns the broker's ranked top-K candidate set and
//!   per-source bandwidth predictions (from [`crate::forecast`]) into a
//!   contiguous byte-range assignment proportional to predicted
//!   throughput.
//! * [`scheduler`] — splits the file into fixed-size blocks and drives
//!   one stream per replica as an event-driven
//!   [`scheduler::CoallocSession`] on the `simnet` kernel: the
//!   streams' blocks are flows in a [`crate::simnet::FlowSet`]
//!   (concurrent flows sharing link and per-client downlink capacity),
//!   and the session work-steals blocks from lagging streams so a
//!   slowing source sheds load to faster peers — including sources
//!   slowed by *other* clients' traffic when several sessions share
//!   one grid-wide kernel (the open-loop runtime). Every block is
//!   instrumented into the source site's
//!   [`crate::gridftp::HistoryStore`] — the co-allocated Access phase
//!   feeds the same selection history as single-source fetches. The
//!   scheduler also survives *churn*: a source that dies or stalls
//!   mid-transfer fails over — its blocks are re-queued to survivors
//!   under the same stealing discipline, with bounded per-block
//!   retries and an exactly-once integrity check (see the module docs'
//!   failover state machine).
//! * [`store`] — the write-direction dual: replica creation pushing
//!   one logical file to several destination sites in parallel, with
//!   the same per-block fault surface.
//!
//! Entry points: [`crate::broker::Broker::select_coalloc`] builds the
//! plan from a live selection; [`execute`] runs it against the grid;
//! [`execute_store`] creates replicas (see `ReplicaManager::
//! create_replicas` for the catalog-registering wrapper). Tuning —
//! block size, stream count, downlink cap, retry budget, stall
//! timeout — lives in [`crate::config::CoallocPolicy`].

pub mod planner;
pub mod scheduler;
pub mod store;

pub use planner::{plan_stripes, StripeAssignment, StripePlan, StripeSource};
pub use scheduler::{execute, CoallocOutcome, CoallocSession, StreamReport};
pub use store::{execute_store, StoreOutcome, StoreStreamReport, StoreTarget};
