//! Runtime values and the three-valued (really four-valued) logic of
//! classic ClassAds: `TRUE`, `FALSE`, `UNDEFINED`, `ERROR`.
//!
//! `UNDEFINED` arises from referencing a missing attribute; `ERROR` from
//! type mismatches (e.g. `"abc" * 3`). Both propagate through strict
//! operators; the lazy boolean operators absorb them when the other
//! operand decides the result (`FALSE && UNDEFINED == FALSE`).

use std::cmp::Ordering;
use std::fmt;

/// A ClassAd runtime value.
#[derive(Debug, Clone)]
pub enum Value {
    Undefined,
    Error,
    Bool(bool),
    Int(i64),
    Real(f64),
    /// A numeric quantity carrying its display unit (`50G`, `75K/Sec`).
    /// Behaves exactly like `Real(bytes)` in arithmetic/comparisons but
    /// unparses in the paper's notation.
    Quantity {
        base: f64,
        rate: bool,
    },
    Str(String),
    List(Vec<Value>),
}

impl Value {
    /// Numeric view (Int, Real, Quantity). None for other types.
    pub fn as_number(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Real(r) => Some(*r),
            Value::Quantity { base, .. } => Some(*base),
            _ => None,
        }
    }

    /// Boolean view. None when the value is not a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_undefined(&self) -> bool {
        matches!(self, Value::Undefined)
    }

    pub fn is_error(&self) -> bool {
        matches!(self, Value::Error)
    }

    /// True when the value is `UNDEFINED` or `ERROR` (propagating).
    pub fn is_exceptional(&self) -> bool {
        self.is_undefined() || self.is_error()
    }

    /// Classic-ClassAd equality used by `==`: numerics compare by value,
    /// strings case-insensitively; mismatched types are an ERROR
    /// (handled by the caller); returns None on type mismatch.
    pub fn loose_eq(&self, other: &Value) -> Option<bool> {
        match (self.as_number(), other.as_number()) {
            (Some(a), Some(b)) => return Some(a == b),
            _ => {}
        }
        match (self, other) {
            (Value::Bool(a), Value::Bool(b)) => Some(a == b),
            (Value::Str(a), Value::Str(b)) => Some(a.eq_ignore_ascii_case(b)),
            _ => None,
        }
    }

    /// Ordering for `<`, `<=`, `>`, `>=`. None on type mismatch.
    pub fn loose_cmp(&self, other: &Value) -> Option<Ordering> {
        if let (Some(a), Some(b)) = (self.as_number(), other.as_number()) {
            return a.partial_cmp(&b);
        }
        match (self, other) {
            (Value::Str(a), Value::Str(b)) => {
                Some(a.to_ascii_lowercase().cmp(&b.to_ascii_lowercase()))
            }
            _ => None,
        }
    }

    /// The strict `=?=` ("is") comparison: never UNDEFINED/ERROR; same
    /// type and same value (strings case-*sensitive*, per Condor).
    pub fn strict_eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Undefined, Value::Undefined) => true,
            (Value::Error, Value::Error) => true,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::List(a), Value::List(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.strict_eq(y))
            }
            _ => match (self.as_number(), other.as_number()) {
                (Some(a), Some(b)) => {
                    // =?= requires same *type* class too: int vs real differ
                    let same_class = matches!(
                        (self, other),
                        (Value::Int(_), Value::Int(_))
                            | (Value::Real(_) | Value::Quantity { .. },
                               Value::Real(_) | Value::Quantity { .. })
                    );
                    same_class && a == b
                }
                _ => false,
            },
        }
    }

    /// Type name for diagnostics and the `typeOf` builtin.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Undefined => "undefined",
            Value::Error => "error",
            Value::Bool(_) => "boolean",
            Value::Int(_) => "integer",
            Value::Real(_) | Value::Quantity { .. } => "real",
            Value::Str(_) => "string",
            Value::List(_) => "list",
        }
    }
}

/// Structural equality, except that `Quantity` is transparent over
/// `Real` (a quantity is just a real with display units — `50G`
/// unparses/reparses through raw-number form when non-integral).
impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Value::Undefined, Value::Undefined) => true,
            (Value::Error, Value::Error) => true,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::List(a), Value::List(b)) => a == b,
            (
                Value::Real(_) | Value::Quantity { .. },
                Value::Real(_) | Value::Quantity { .. },
            ) => self.as_number() == other.as_number(),
            _ => false,
        }
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}
impl From<f64> for Value {
    fn from(r: f64) -> Self {
        Value::Real(r)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

/// `Display` writes the *ClassAd text form* (strings quoted, quantities
/// with unit suffixes) so that unparsed ads re-parse to the same ad.
impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Undefined => write!(f, "UNDEFINED"),
            Value::Error => write!(f, "ERROR"),
            Value::Bool(b) => write!(f, "{}", if *b { "TRUE" } else { "FALSE" }),
            Value::Int(i) => write!(f, "{i}"),
            Value::Real(r) => {
                if r.fract() == 0.0 && r.abs() < 1e15 {
                    write!(f, "{:.1}", r)
                } else {
                    write!(f, "{r}")
                }
            }
            Value::Quantity { base, rate } => {
                write!(f, "{}", crate::util::units::format_quantity(*base, *rate))
            }
            Value::Str(s) => {
                write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\""))
            }
            Value::List(xs) => {
                write!(f, "{{")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loose_eq_numeric_promotes() {
        assert_eq!(Value::Int(3).loose_eq(&Value::Real(3.0)), Some(true));
        assert_eq!(
            Value::Quantity { base: 1024.0, rate: false }.loose_eq(&Value::Int(1024)),
            Some(true)
        );
    }

    #[test]
    fn loose_eq_strings_case_insensitive() {
        assert_eq!(
            Value::from("Hugo.MCS.anl.gov").loose_eq(&Value::from("hugo.mcs.anl.gov")),
            Some(true)
        );
    }

    #[test]
    fn loose_eq_type_mismatch_is_none() {
        assert_eq!(Value::Int(1).loose_eq(&Value::from("1")), None);
        assert_eq!(Value::Bool(true).loose_eq(&Value::Int(1)), None);
    }

    #[test]
    fn strict_eq_discriminates_types() {
        assert!(Value::Undefined.strict_eq(&Value::Undefined));
        assert!(!Value::Int(3).strict_eq(&Value::Real(3.0)));
        assert!(Value::Real(3.0).strict_eq(&Value::Quantity { base: 3.0, rate: false }));
        assert!(!Value::from("A").strict_eq(&Value::from("a")));
    }

    #[test]
    fn display_round_trip_forms() {
        assert_eq!(Value::from("a\"b").to_string(), "\"a\\\"b\"");
        assert_eq!(Value::Bool(true).to_string(), "TRUE");
        assert_eq!(
            Value::Quantity { base: 50.0 * 1024f64.powi(3), rate: false }.to_string(),
            "50G"
        );
        assert_eq!(
            Value::Quantity { base: 75.0 * 1024.0, rate: true }.to_string(),
            "75K/Sec"
        );
    }

    #[test]
    fn ordering_numeric_and_string() {
        use std::cmp::Ordering::*;
        assert_eq!(Value::Int(2).loose_cmp(&Value::Real(3.0)), Some(Less));
        assert_eq!(Value::from("b").loose_cmp(&Value::from("A")), Some(Greater));
        assert_eq!(Value::Int(1).loose_cmp(&Value::from("x")), None);
    }
}
