//! Bytecode matchmaking: `requirements`/`rank` flattened onto a postfix
//! [`Program`] executed by a stack VM over a dense [`CandidateTable`].
//!
//! The broker's Match phase evaluates one request ad against *every*
//! candidate replica, so the per-candidate evaluator is the hot loop.
//! [`super::compile::CompiledMatch`] already hoists attribute lookup and
//! constant folding out of that loop; this module removes the remaining
//! per-candidate tree walk. Compilation happens in two phases:
//!
//! 1. **Resolve** ([`resolve`]): the folded `Expr` is rewritten against
//!    the request-ad snapshot. Request-side attribute references whose
//!    transitive evaluation can never touch the candidate are evaluated
//!    *now* — at their exact structural depth, through the reference
//!    tree-walker — and inlined as constants; the rewrite then re-folds
//!    around them (`5 < cutoff` with `cutoff = 10` in the request
//!    collapses to `TRUE` before any candidate is seen, and a decided
//!    lazy operand can delete its other arm outright). Candidate-side
//!    references become pre-bound `Sym` slots.
//! 2. **Emit**: the resolved tree is lowered to one contiguous postfix
//!    op vector. Short-circuit `&&`/`||` and the ternary become jump
//!    ops ([`Op::ShortCircuit`], [`Op::Branch`]), so a non-matching
//!    candidate exits in a handful of ops instead of walking the whole
//!    tree. `requirements` and `rank` are two ranges of the same
//!    vector.
//!
//! Execution runs over a reusable [`VmScratch`]. The value stack holds
//! [`Slot`]s — indices into the constant pool, the candidate table, or
//! the candidate ad — so constants and table cells are *referenced*,
//! never cloned: steady-state evaluation of the common numeric
//! requirements performs **zero heap allocations per candidate**. The
//! two exceptions are inherent and deliberately rare: builtin calls
//! copy their (already evaluated) arguments into the scratch argument
//! buffer (a heap copy only for string arguments), and a reference to
//! an attribute *defined by a non-literal expression* falls back to a
//! one-op escape hatch ([`Op::Load`] → `eval::resolve_at_depth`) that
//! re-enters the reference tree-walker for exactly that subtree, so
//! cycle detection and depth budgeting cannot fork from the reference
//! semantics.
//!
//! For batch matching, [`CandidateTable`] converts the Search results
//! once into struct-of-arrays form: one column per attribute the
//! program actually references, keyed by [`Sym`], misses stored as
//! UNDEFINED (mirroring the kernel's `FlowSet` rewrite). The Match
//! phase is then one linear pass down the columns.
//!
//! **Parity rule:** the tree-walker in [`super::eval`] is the reference
//! evaluator. The VM's verdicts and ranks must be bit-identical to it —
//! UNDEFINED/ERROR propagation, case-insensitivity, cycle detection and
//! the `regexp()` builtin included. Both evaluators share one body for
//! every operator (`apply_unary`/`apply_binary`/`lazy_decided`/
//! `lazy_combine`/`call_vals`), and `it_match_parity` plus a randomized
//! differential property test in `prop_invariants` pin the equivalence.
//! Constants are *not* deduplicated: `Value`'s `PartialEq` is
//! transparent across `Quantity`/`Real` while their `Display` differs,
//! so merging "equal" constants could change `string()`/`strcat`
//! output.

use super::ast::{BinOp, ClassAd, Expr, Scope, UnOp};
use super::eval::{self, builtins, EvalCtx, MAX_DEPTH};
use super::intern::Sym;
use super::value::Value;

/// One postfix instruction. Jump targets are absolute indices into the
/// program's op vector (sections are contiguous, so an in-section
/// target never escapes its range).
#[derive(Debug, Clone, Copy)]
enum Op {
    /// Push constant pool entry `i` (by reference).
    Const(u32),
    /// Push the resolution of attribute slot `i` (table cell, ad
    /// literal, or tree-walk escape hatch).
    Load(u32),
    Unary(UnOp),
    /// Strict binary operators only — `&&`/`||` lower to
    /// [`Op::ShortCircuit`] + [`Op::Combine`].
    Binary(BinOp),
    /// Lazy-operator gate: inspects the left operand on top of the
    /// stack. If it decides the result (`FALSE &&`, `TRUE ||`, ERROR or
    /// non-boolean), replaces it and jumps to `end`, skipping the right
    /// operand entirely — exactly the tree-walker's early return.
    ShortCircuit { or: bool, end: u32 },
    /// Lazy-operator join: pops right then left, pushes the
    /// UNDEFINED-absorbing combination.
    Combine { or: bool },
    /// Ternary gate: pops the condition. TRUE falls through to the then
    /// branch, FALSE jumps to `on_false`, UNDEFINED/ERROR push the
    /// propagated value and jump to `end`.
    Branch { on_false: u32, end: u32 },
    Jump(u32),
    /// Pop `argc` arguments into the scratch buffer, dispatch builtin
    /// `names[name]`.
    Call { name: u32, argc: u32 },
    MakeList(u32),
}

/// A pre-bound attribute reference: which side, which symbol, the
/// structural depth of the originating `Attr` node (the tree-walker's
/// depth budget must see the same number), and — for candidate-side
/// references — the [`CandidateTable`] column.
#[derive(Debug, Clone, Copy)]
struct VmAttr {
    other: bool,
    sym: Sym,
    depth: u32,
    /// Column index for candidate-side attributes; `u32::MAX` for
    /// request-side (candidate-dependent) references, which always take
    /// the escape hatch.
    col: u32,
}

const NO_COL: u32 = u32::MAX;

/// A value-stack entry. Constants and table cells stay where they are —
/// only computed intermediate results are owned.
#[derive(Debug)]
enum Slot {
    /// Constant pool entry.
    Const(u32),
    /// Candidate-table cell in the current row.
    Cell(u32),
    /// Literal attribute of the current candidate ad (ad-mode only).
    AdLit(Sym),
    /// Computed intermediate.
    Owned(Value),
}

/// Reusable VM state: the value stack and the builtin-argument buffer.
/// One per `SelectScratch`; capacity persists across candidates and
/// calls, so steady-state execution never grows it.
#[derive(Debug, Default)]
pub struct VmScratch {
    stack: Vec<Slot>,
    args: Vec<Value>,
}

/// One dense cell of a [`CandidateTable`] column.
#[derive(Debug, Clone)]
enum TCell {
    /// The attribute's literal value (or UNDEFINED for a miss) —
    /// readable without touching the ad.
    Val(Value),
    /// The attribute is defined by a non-literal expression; loads take
    /// the tree-walk escape hatch against the candidate ad.
    Escape,
}

/// Struct-of-arrays view of a candidate batch: one column per attribute
/// the program references on the candidate side, `cols[col][row]`.
/// Rebuilt per batch (capacity reused), read per candidate.
#[derive(Debug, Default, Clone)]
pub struct CandidateTable {
    cols: Vec<Vec<TCell>>,
    rows: usize,
}

impl CandidateTable {
    /// Re-populate from a candidate batch for `program`. Column
    /// vectors are cleared, not dropped, so a steady-state broker
    /// reuses their capacity; only string-valued literal cells copy
    /// heap data, and only once per batch (not per op).
    pub fn rebuild<'a, I>(&mut self, program: &Program, ads: I)
    where
        I: IntoIterator<Item = &'a ClassAd>,
    {
        let ncols = program.columns.len();
        self.cols.truncate(ncols);
        while self.cols.len() < ncols {
            self.cols.push(Vec::new());
        }
        for col in &mut self.cols {
            col.clear();
        }
        self.rows = 0;
        for ad in ads {
            for (ci, &sym) in program.columns.iter().enumerate() {
                let cell = match ad.get_sym(sym) {
                    None => TCell::Val(Value::Undefined),
                    Some(Expr::Lit(v)) => TCell::Val(v.clone()),
                    Some(_) => TCell::Escape,
                };
                self.cols[ci].push(cell);
            }
            self.rows += 1;
        }
    }

    /// Number of candidate rows currently held.
    pub fn rows(&self) -> usize {
        self.rows
    }

    fn cell(&self, col: usize, row: usize) -> &TCell {
        &self.cols[col][row]
    }
}

/// Everything a single candidate evaluation can read.
#[derive(Clone, Copy)]
struct VmEnv<'a> {
    request: &'a ClassAd,
    candidate: &'a ClassAd,
    table: Option<(&'a CandidateTable, usize)>,
}

/// A request's `requirements` + `rank`, compiled to postfix bytecode.
/// Produced by [`Program::compile`] against a request-ad snapshot; the
/// same snapshot must be passed back at execution time
/// ([`super::compile::CompiledMatch`] owns both and guarantees this).
#[derive(Debug, Clone)]
pub struct Program {
    ops: Vec<Op>,
    consts: Vec<Value>,
    attrs: Vec<VmAttr>,
    names: Vec<String>,
    /// Candidate-side attribute symbols, in column order.
    columns: Vec<Sym>,
    /// `[start, end)` op range of the requirements section; `None` =
    /// the request publishes none = always willing.
    req: Option<(u32, u32)>,
    /// `[start, end)` op range of the rank section; `None` ranks 0.
    rank: Option<(u32, u32)>,
}

impl Program {
    /// Compile the request's (already folded) `requirements` and `rank`
    /// expressions. Request-side constant inlining evaluates against
    /// `request` *now*; the returned program is a snapshot, like the
    /// rest of `CompiledMatch`.
    pub fn compile(request: &ClassAd, requirements: Option<&Expr>, rank: Option<&Expr>) -> Program {
        let mut em = Emitter::default();
        let req = requirements.map(|e| em.emit_section(request, e));
        let rank = rank.map(|e| em.emit_section(request, e));
        Program {
            ops: em.ops,
            consts: em.consts,
            attrs: em.attrs,
            names: em.names,
            columns: em.columns,
            req,
            rank,
        }
    }

    /// Does the *request* side accept `candidate`? (The candidate's own
    /// requirements are the caller's business, as in `CompiledMatch`.)
    pub fn holds(&self, request: &ClassAd, candidate: &ClassAd, scratch: &mut VmScratch) -> bool {
        self.holds_env(&VmEnv { request, candidate, table: None }, scratch)
    }

    /// [`Program::holds`] reading candidate attributes from table row
    /// `row` instead of probing the ad.
    pub fn holds_row(
        &self,
        request: &ClassAd,
        candidate: &ClassAd,
        table: &CandidateTable,
        row: usize,
        scratch: &mut VmScratch,
    ) -> bool {
        self.holds_env(&VmEnv { request, candidate, table: Some((table, row)) }, scratch)
    }

    /// The request's rank of `candidate` (non-numeric collapses to 0.0,
    /// as in the tree-walking `CompiledMatch::rank`).
    pub fn rank(&self, request: &ClassAd, candidate: &ClassAd, scratch: &mut VmScratch) -> f64 {
        self.rank_env(&VmEnv { request, candidate, table: None }, scratch)
    }

    /// [`Program::rank`] reading candidate attributes from table row `row`.
    pub fn rank_row(
        &self,
        request: &ClassAd,
        candidate: &ClassAd,
        table: &CandidateTable,
        row: usize,
        scratch: &mut VmScratch,
    ) -> f64 {
        self.rank_env(&VmEnv { request, candidate, table: Some((table, row)) }, scratch)
    }

    /// Total op count across both sections (compile-quality metric:
    /// request-side inlining shows up as fewer ops).
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }

    /// Number of candidate-side attribute columns the table carries.
    pub fn column_count(&self) -> usize {
        self.columns.len()
    }

    fn holds_env(&self, env: &VmEnv<'_>, scratch: &mut VmScratch) -> bool {
        match self.req {
            None => true,
            Some(range) => {
                let top = self.run(range, env, scratch);
                matches!(self.slot_value(env, &top), Value::Bool(true))
            }
        }
    }

    fn rank_env(&self, env: &VmEnv<'_>, scratch: &mut VmScratch) -> f64 {
        match self.rank {
            None => 0.0,
            Some(range) => {
                let top = self.run(range, env, scratch);
                self.slot_value(env, &top).as_number().unwrap_or(0.0)
            }
        }
    }

    /// Execute one section; returns the result slot. The interpreter
    /// loop allocates nothing itself — every push is an index slot or
    /// an `Owned` value computed by the shared operator bodies.
    fn run(&self, (start, end): (u32, u32), env: &VmEnv<'_>, scratch: &mut VmScratch) -> Slot {
        let VmScratch { stack, args } = scratch;
        stack.clear();
        let end = end as usize;
        let mut pc = start as usize;
        while pc < end {
            match &self.ops[pc] {
                Op::Const(i) => stack.push(Slot::Const(*i)),
                Op::Load(i) => stack.push(self.load(*i, env)),
                Op::Unary(op) => {
                    let x = stack.pop().expect("vm: unary underflow");
                    let v = eval::apply_unary(*op, self.slot_value(env, &x));
                    stack.push(Slot::Owned(v));
                }
                Op::Binary(op) => {
                    let r = stack.pop().expect("vm: binary underflow");
                    let l = stack.pop().expect("vm: binary underflow");
                    let v =
                        eval::apply_binary(*op, self.slot_value(env, &l), self.slot_value(env, &r));
                    stack.push(Slot::Owned(v));
                }
                Op::ShortCircuit { or, end: target } => {
                    let top = stack.last().expect("vm: short-circuit underflow");
                    if let Some(v) = eval::lazy_decided(*or, self.slot_value(env, top)) {
                        *stack.last_mut().expect("vm: short-circuit underflow") = Slot::Owned(v);
                        pc = *target as usize;
                        continue;
                    }
                }
                Op::Combine { or } => {
                    let r = stack.pop().expect("vm: combine underflow");
                    let l = stack.pop().expect("vm: combine underflow");
                    let v =
                        eval::lazy_combine(*or, self.slot_value(env, &l), self.slot_value(env, &r));
                    stack.push(Slot::Owned(v));
                }
                Op::Branch { on_false, end: target } => {
                    let c = stack.pop().expect("vm: branch underflow");
                    match self.slot_value(env, &c) {
                        Value::Bool(true) => {}
                        Value::Bool(false) => {
                            pc = *on_false as usize;
                            continue;
                        }
                        Value::Undefined => {
                            stack.push(Slot::Owned(Value::Undefined));
                            pc = *target as usize;
                            continue;
                        }
                        _ => {
                            stack.push(Slot::Owned(Value::Error));
                            pc = *target as usize;
                            continue;
                        }
                    }
                }
                Op::Jump(target) => {
                    pc = *target as usize;
                    continue;
                }
                Op::Call { name, argc } => {
                    let argc = *argc as usize;
                    args.clear();
                    let base = stack.len() - argc;
                    for s in stack.drain(base..) {
                        args.push(self.slot_value(env, &s).clone());
                    }
                    let v = builtins::call_vals(&self.names[*name as usize], &args[..]);
                    stack.push(Slot::Owned(v));
                }
                Op::MakeList(n) => {
                    let base = stack.len() - *n as usize;
                    let vs: Vec<Value> =
                        stack.drain(base..).map(|s| self.slot_value(env, &s).clone()).collect();
                    stack.push(Slot::Owned(Value::List(vs)));
                }
            }
            pc += 1;
        }
        stack.pop().expect("vm: section left no result")
    }

    /// Resolve attribute slot `i` to a stack slot. Literal values stay
    /// by-reference (table cell or ad entry); anything defined by an
    /// expression re-enters the reference tree-walker at the baked
    /// structural depth.
    fn load(&self, i: u32, env: &VmEnv<'_>) -> Slot {
        let a = &self.attrs[i as usize];
        if !a.other {
            // Candidate-dependent request-side reference: full
            // resolution, my-side first (Scope::My and present-Default
            // behave identically here — compile guarantees presence).
            let ctx = EvalCtx::matched(env.request, env.candidate);
            return Slot::Owned(eval::resolve_at_depth(ctx, false, a.sym, a.depth as usize));
        }
        if let Some((table, row)) = env.table {
            return match table.cell(a.col as usize, row) {
                TCell::Val(_) => Slot::Cell(a.col),
                TCell::Escape => {
                    let ctx = EvalCtx::matched(env.request, env.candidate);
                    Slot::Owned(eval::resolve_at_depth(ctx, true, a.sym, a.depth as usize))
                }
            };
        }
        match env.candidate.get_sym(a.sym) {
            None => Slot::Owned(Value::Undefined),
            Some(Expr::Lit(_)) => Slot::AdLit(a.sym),
            Some(_) => {
                let ctx = EvalCtx::matched(env.request, env.candidate);
                Slot::Owned(eval::resolve_at_depth(ctx, true, a.sym, a.depth as usize))
            }
        }
    }

    fn slot_value<'a>(&'a self, env: &VmEnv<'a>, slot: &'a Slot) -> &'a Value {
        match slot {
            Slot::Owned(v) => v,
            Slot::Const(i) => &self.consts[*i as usize],
            Slot::Cell(col) => {
                let (table, row) = env.table.expect("vm: cell slot without a table");
                match table.cell(*col as usize, row) {
                    TCell::Val(v) => v,
                    TCell::Escape => unreachable!("vm: escape cells resolve at load"),
                }
            }
            Slot::AdLit(sym) => match env.candidate.get_sym(*sym) {
                Some(Expr::Lit(v)) => v,
                _ => unreachable!("vm: ad-lit slot must name a literal attribute"),
            },
        }
    }
}

/// Resolved tree: the intermediate between the request-side rewrite and
/// postfix emission. `Const` nodes carry the exact value the reference
/// tree-walker produces for that subtree at that depth.
enum RNode {
    Const(Value),
    Attr { other: bool, sym: Sym, depth: u32 },
    Unary(UnOp, Box<RNode>),
    Binary(BinOp, Box<RNode>, Box<RNode>),
    Cond(Box<RNode>, Box<RNode>, Box<RNode>),
    Call(String, Vec<RNode>),
    List(Vec<RNode>),
}

/// Phase 1: rewrite `e` against the request snapshot. The induction
/// invariant is exact equivalence: `RNode::Const(v)` means the
/// reference evaluator produces precisely `v` for this subtree at this
/// structural depth for *every* candidate — which is why folding uses
/// the same shared operator bodies the tree-walker runs, depths are
/// baked into `Attr` nodes, and nodes past the depth budget become
/// `Const(Error)` exactly where `eval_inner` would bail.
fn resolve(request: &ClassAd, e: &Expr, depth: usize) -> RNode {
    if depth > MAX_DEPTH {
        return RNode::Const(Value::Error);
    }
    let d = depth as u32;
    match e {
        Expr::Lit(v) => RNode::Const(v.clone()),
        Expr::Attr(scope, name) => {
            let sym = name.sym();
            let present = request.contains_sym(sym);
            match scope {
                Scope::Other => RNode::Attr { other: true, sym, depth: d },
                Scope::My if !present => RNode::Const(Value::Undefined),
                // Default with no request-side definition falls through
                // to the candidate, statically.
                Scope::Default if !present => RNode::Attr { other: true, sym, depth: d },
                Scope::My | Scope::Default => {
                    let defn = request.get_sym(sym).expect("present implies defined");
                    let mut visiting = Vec::new();
                    if candidate_dependent(request, defn, &mut visiting) {
                        RNode::Attr { other: false, sym, depth: d }
                    } else {
                        // Candidate-independent: the value is fixed for
                        // every candidate. Evaluate through the
                        // reference walker at the node's exact depth —
                        // solo context, since the evaluation provably
                        // never reaches the other side.
                        RNode::Const(eval::resolve_at_depth(
                            EvalCtx::solo(request),
                            false,
                            sym,
                            depth,
                        ))
                    }
                }
            }
        }
        Expr::Unary(op, x) => match resolve(request, x, depth + 1) {
            RNode::Const(v) => RNode::Const(eval::apply_unary(*op, &v)),
            rx => RNode::Unary(*op, Box::new(rx)),
        },
        Expr::Binary(op, l, r) if matches!(op, BinOp::And | BinOp::Or) => {
            let or = *op == BinOp::Or;
            let rl = resolve(request, l, depth + 1);
            if let RNode::Const(lv) = &rl {
                if let Some(v) = eval::lazy_decided(or, lv) {
                    // Decided left operand: the right arm is never
                    // evaluated, so it is deleted, not compiled.
                    return RNode::Const(v);
                }
                let rr = resolve(request, r, depth + 1);
                if let RNode::Const(rv) = &rr {
                    return RNode::Const(eval::lazy_combine(or, lv, rv));
                }
                return RNode::Binary(*op, Box::new(rl), Box::new(rr));
            }
            let rr = resolve(request, r, depth + 1);
            RNode::Binary(*op, Box::new(rl), Box::new(rr))
        }
        Expr::Binary(op, l, r) => {
            let rl = resolve(request, l, depth + 1);
            let rr = resolve(request, r, depth + 1);
            match (&rl, &rr) {
                (RNode::Const(lv), RNode::Const(rv)) => {
                    RNode::Const(eval::apply_binary(*op, lv, rv))
                }
                _ => RNode::Binary(*op, Box::new(rl), Box::new(rr)),
            }
        }
        Expr::Cond(c, t, f) => match resolve(request, c, depth + 1) {
            // A constant condition splices the taken branch in place;
            // branch depths stay correct because they were resolved at
            // their own structural depth.
            RNode::Const(Value::Bool(true)) => resolve(request, t, depth + 1),
            RNode::Const(Value::Bool(false)) => resolve(request, f, depth + 1),
            RNode::Const(Value::Undefined) => RNode::Const(Value::Undefined),
            RNode::Const(_) => RNode::Const(Value::Error),
            rc => RNode::Cond(
                Box::new(rc),
                Box::new(resolve(request, t, depth + 1)),
                Box::new(resolve(request, f, depth + 1)),
            ),
        },
        Expr::Call(name, xs) => {
            let rs: Vec<RNode> = xs.iter().map(|x| resolve(request, x, depth + 1)).collect();
            if rs.iter().all(|r| matches!(r, RNode::Const(_))) {
                let vals: Vec<Value> = rs
                    .iter()
                    .map(|r| match r {
                        RNode::Const(v) => v.clone(),
                        _ => unreachable!(),
                    })
                    .collect();
                RNode::Const(builtins::call_vals(name, &vals))
            } else {
                RNode::Call(name.clone(), rs)
            }
        }
        Expr::List(xs) => {
            let rs: Vec<RNode> = xs.iter().map(|x| resolve(request, x, depth + 1)).collect();
            if rs.iter().all(|r| matches!(r, RNode::Const(_))) {
                let vals: Vec<Value> = rs
                    .iter()
                    .map(|r| match r {
                        RNode::Const(v) => v.clone(),
                        _ => unreachable!(),
                    })
                    .collect();
                RNode::Const(Value::List(vals))
            } else {
                RNode::List(rs)
            }
        }
    }
}

/// Can evaluating `e` in the request's match context ever touch the
/// candidate ad? Conservative (`true` when unsure) — a `true` only
/// costs an escape-hatch op, a wrong `false` would fork semantics.
///
/// A reference is candidate-dependent iff it reaches `other.` scope or
/// a Default-scope name absent from the request (which falls through to
/// the candidate). A *pure request-side cycle* is independent: it
/// evaluates to ERROR before the candidate could matter, so the cyclic
/// edge itself is skipped (`visiting`) while its siblings are still
/// explored.
fn candidate_dependent(request: &ClassAd, e: &Expr, visiting: &mut Vec<Sym>) -> bool {
    match e {
        Expr::Lit(_) => false,
        Expr::Attr(scope, name) => {
            let sym = name.sym();
            match scope {
                Scope::Other => true,
                Scope::My | Scope::Default => match request.get_sym(sym) {
                    Some(defn) => {
                        if visiting.contains(&sym) {
                            false
                        } else {
                            visiting.push(sym);
                            let dep = candidate_dependent(request, defn, visiting);
                            visiting.pop();
                            dep
                        }
                    }
                    None => matches!(scope, Scope::Default),
                },
            }
        }
        Expr::Unary(_, x) => candidate_dependent(request, x, visiting),
        Expr::Binary(_, l, r) => {
            candidate_dependent(request, l, visiting) || candidate_dependent(request, r, visiting)
        }
        Expr::Cond(c, t, f) => {
            candidate_dependent(request, c, visiting)
                || candidate_dependent(request, t, visiting)
                || candidate_dependent(request, f, visiting)
        }
        Expr::Call(_, args) => args.iter().any(|a| candidate_dependent(request, a, visiting)),
        Expr::List(xs) => xs.iter().any(|x| candidate_dependent(request, x, visiting)),
    }
}

/// Phase 2: postfix emission with jump backpatching.
#[derive(Default)]
struct Emitter {
    ops: Vec<Op>,
    consts: Vec<Value>,
    attrs: Vec<VmAttr>,
    names: Vec<String>,
    columns: Vec<Sym>,
}

impl Emitter {
    fn emit_section(&mut self, request: &ClassAd, e: &Expr) -> (u32, u32) {
        let start = self.ops.len() as u32;
        let node = resolve(request, e, 0);
        self.emit(&node);
        (start, self.ops.len() as u32)
    }

    fn emit(&mut self, n: &RNode) {
        match n {
            RNode::Const(v) => {
                // No dedup — see the module doc's Quantity/Real note.
                let i = self.consts.len() as u32;
                self.consts.push(v.clone());
                self.ops.push(Op::Const(i));
            }
            RNode::Attr { other, sym, depth } => {
                let i = self.attr_slot(*other, *sym, *depth);
                self.ops.push(Op::Load(i));
            }
            RNode::Unary(op, x) => {
                self.emit(x);
                self.ops.push(Op::Unary(*op));
            }
            RNode::Binary(op, l, r) if matches!(op, BinOp::And | BinOp::Or) => {
                let or = *op == BinOp::Or;
                self.emit(l);
                let sc = self.ops.len();
                self.ops.push(Op::ShortCircuit { or, end: 0 });
                self.emit(r);
                self.ops.push(Op::Combine { or });
                let end = self.ops.len() as u32;
                if let Op::ShortCircuit { end: e, .. } = &mut self.ops[sc] {
                    *e = end;
                }
            }
            RNode::Binary(op, l, r) => {
                self.emit(l);
                self.emit(r);
                self.ops.push(Op::Binary(*op));
            }
            RNode::Cond(c, t, f) => {
                self.emit(c);
                let br = self.ops.len();
                self.ops.push(Op::Branch { on_false: 0, end: 0 });
                self.emit(t);
                let jmp = self.ops.len();
                self.ops.push(Op::Jump(0));
                let on_false = self.ops.len() as u32;
                self.emit(f);
                let end = self.ops.len() as u32;
                if let Op::Branch { on_false: of, end: e } = &mut self.ops[br] {
                    *of = on_false;
                    *e = end;
                }
                if let Op::Jump(t) = &mut self.ops[jmp] {
                    *t = end;
                }
            }
            RNode::Call(name, xs) => {
                for x in xs {
                    self.emit(x);
                }
                let ni = self.name_slot(name);
                self.ops.push(Op::Call { name: ni, argc: xs.len() as u32 });
            }
            RNode::List(xs) => {
                for x in xs {
                    self.emit(x);
                }
                self.ops.push(Op::MakeList(xs.len() as u32));
            }
        }
    }

    fn attr_slot(&mut self, other: bool, sym: Sym, depth: u32) -> u32 {
        if let Some(i) = self
            .attrs
            .iter()
            .position(|a| a.other == other && a.sym == sym && a.depth == depth)
        {
            return i as u32;
        }
        let col = if other {
            match self.columns.iter().position(|s| s.id() == sym.id()) {
                Some(c) => c as u32,
                None => {
                    self.columns.push(sym);
                    (self.columns.len() - 1) as u32
                }
            }
        } else {
            NO_COL
        };
        self.attrs.push(VmAttr { other, sym, depth, col });
        (self.attrs.len() - 1) as u32
    }

    fn name_slot(&mut self, name: &str) -> u32 {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            return i as u32;
        }
        self.names.push(name.to_string());
        (self.names.len() - 1) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::compile::fold;
    use crate::classad::eval::eval;
    use crate::classad::parser::{parse_classad, parse_expr};

    fn tree_value(request: &ClassAd, candidate: &ClassAd, e: &Expr) -> Value {
        eval(EvalCtx::matched(request, candidate), e)
    }

    /// VM-vs-tree on one expression used as both requirements and rank.
    fn assert_expr_parity(request: &ClassAd, candidate: &ClassAd, src: &str) {
        let e = fold(&parse_expr(src).unwrap());
        let p = Program::compile(request, Some(&e), Some(&e));
        let mut vm = VmScratch::default();
        let tv = tree_value(request, candidate, &e);
        assert_eq!(
            p.holds(request, candidate, &mut vm),
            matches!(tv, Value::Bool(true)),
            "holds parity for `{src}` (tree said {tv:?})"
        );
        let tree_rank = tv.as_number().unwrap_or(0.0);
        let vm_rank = p.rank(request, candidate, &mut vm);
        assert_eq!(
            vm_rank.to_bits(),
            tree_rank.to_bits(),
            "rank bits for `{src}` (tree {tree_rank}, vm {vm_rank})"
        );
        // Table mode must agree with ad mode.
        let mut table = CandidateTable::default();
        table.rebuild(&p, std::iter::once(candidate));
        assert_eq!(
            p.holds_row(request, candidate, &table, 0, &mut vm),
            matches!(tv, Value::Bool(true)),
            "table-mode holds parity for `{src}`"
        );
        assert_eq!(
            p.rank_row(request, candidate, &table, 0, &mut vm).to_bits(),
            tree_rank.to_bits(),
            "table-mode rank bits for `{src}`"
        );
    }

    #[test]
    fn request_side_constants_are_inlined() {
        let request = parse_classad("cutoff = 5;").unwrap();
        let e = fold(&parse_expr("other.size > cutoff").unwrap());
        let p = Program::compile(&request, Some(&e), None);
        // Load, Const, Binary — the `cutoff` lookup is gone.
        assert_eq!(p.op_count(), 3);
        assert_eq!(p.column_count(), 1);
        let mut vm = VmScratch::default();
        for (src, want) in [("size = 7;", true), ("size = 3;", false), ("x = 1;", false)] {
            let cand = parse_classad(src).unwrap();
            assert_eq!(p.holds(&request, &cand, &mut vm), want, "candidate `{src}`");
        }
    }

    #[test]
    fn paper_ads_match_and_rank_identically() {
        let request = parse_classad(
            r#"
            reqdSpace = 5G;
            reqdRDBandwidth = 50K/Sec;
            rank = other.availableSpace;
            requirement = other.availableSpace > 5G
                && other.MaxRDBandwidth > 50K/Sec;
            "#,
        )
        .unwrap();
        for cand_src in [
            "availableSpace = 50G; MaxRDBandwidth = 75K/Sec;",
            "availableSpace = 3G; MaxRDBandwidth = 75K/Sec;",
            "availableSpace = 50G;",
            "hostname = \"x\";",
        ] {
            let cand = parse_classad(cand_src).unwrap();
            assert_expr_parity(
                &request,
                &cand,
                "other.availableSpace > 5G && other.MaxRDBandwidth > 50K/Sec",
            );
            assert_expr_parity(&request, &cand, "other.availableSpace");
        }
    }

    #[test]
    fn exceptional_logic_and_jumps_agree_with_tree() {
        let request = parse_classad("threshold = 10; bad = 1 / 0;").unwrap();
        let cand = parse_classad("a = 3; s = \"Replica\"; derived = a * 2; cyc = cyc;").unwrap();
        for src in [
            // Short-circuits: decided, absorbing, error-poisoned.
            "other.a < 0 && other.nosuch",
            "other.nosuch || other.a > 1",
            "other.a && other.a > 1",
            "my.bad || other.a > 1",
            // Ternary on every condition class.
            "other.a > 1 ? 1 : 2",
            "other.a < 1 ? 1 : 2",
            "other.nosuch ? 1 : 2",
            "other.s ? 1 : 2",
            // Builtins, lists, strings, regex, case-insensitivity.
            "regexp(\"repl.*\", other.s)",
            "member(other.a, {1, 2, 3})",
            "strcat(other.s, \"!\") == \"replica!\"",
            "substr(other.s, 0, 3)",
            "isUndefined(other.nosuch)",
            // Escape hatch: expression-defined and cyclic candidate attrs.
            "other.derived > threshold",
            "other.derived > 5",
            "other.cyc == 1",
            // Strict ops see through exceptional values.
            "other.nosuch =?= UNDEFINED",
            "my.bad =!= ERROR",
        ] {
            assert_expr_parity(&request, &cand, src);
        }
    }

    #[test]
    fn request_side_cycles_inline_to_error() {
        let request = parse_classad("loop = loop + 1; rank = loop;").unwrap();
        let cand = parse_classad("a = 1;").unwrap();
        // Pure request-side cycle is candidate-independent → ERROR const.
        let e = fold(&parse_expr("loop > 0").unwrap());
        let p = Program::compile(&request, Some(&e), None);
        assert_eq!(p.column_count(), 0, "no candidate columns for a pure request cycle");
        assert_expr_parity(&request, &cand, "loop > 0");
        // A cycle with a candidate-dependent sibling keeps the load.
        let request2 = parse_classad("x = y + other.a; y = x;").unwrap();
        assert_expr_parity(&request2, &cand, "x > 0");
        assert_expr_parity(&request2, &cand, "y > 0");
    }

    #[test]
    fn table_rebuild_reuses_columns_and_marks_escapes() {
        let request = parse_classad("r = other.space > 10 && other.dyn > 1;").unwrap();
        let e = fold(&parse_expr("other.space > 10 && other.dyn > 1").unwrap());
        let p = Program::compile(&request, Some(&e), None);
        assert_eq!(p.column_count(), 2);
        let ads: Vec<ClassAd> = [
            "space = 50; dyn = space / 2;",
            "space = 5; dyn = 2;",
            "dyn = 2;",
        ]
        .iter()
        .map(|s| parse_classad(s).unwrap())
        .collect();
        let mut table = CandidateTable::default();
        table.rebuild(&p, ads.iter());
        assert_eq!(table.rows(), 3);
        let mut vm = VmScratch::default();
        for (row, ad) in ads.iter().enumerate() {
            assert_eq!(
                p.holds_row(&request, ad, &table, row, &mut vm),
                p.holds(&request, ad, &mut vm),
                "row {row}"
            );
        }
        // Rebuild with fewer rows must fully replace the contents.
        table.rebuild(&p, ads.iter().take(1));
        assert_eq!(table.rows(), 1);
    }

    #[test]
    fn quantity_and_real_constants_stay_distinct() {
        // 50K (Quantity) and 51200.0 (Real) compare equal but print
        // differently; inlining must not merge them.
        let request = parse_classad("q = 50K; r = 51200.0;").unwrap();
        let cand = parse_classad("a = 1;").unwrap();
        assert_expr_parity(&request, &cand, "strcat(string(q), \"/\", string(r))");
    }
}
