//! Fluent programmatic construction of ClassAds.
//!
//! The broker's LDIF→ClassAd conversion layer (paper §6: "primitive
//! libraries to achieve the conversion") and the examples build ads in
//! code; this builder keeps that code readable.

use super::ast::{ClassAd, Expr};
use super::parser::{parse_expr, ParseError};
use super::value::Value;

/// Builder for a [`ClassAd`].
#[derive(Debug, Default, Clone)]
pub struct AdBuilder {
    ad: ClassAd,
}

impl AdBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set a literal string attribute.
    pub fn str(mut self, name: &str, v: impl Into<String>) -> Self {
        self.ad.set_value(name, Value::Str(v.into()));
        self
    }

    /// Set a literal integer attribute.
    pub fn int(mut self, name: &str, v: i64) -> Self {
        self.ad.set_value(name, Value::Int(v));
        self
    }

    /// Set a literal real attribute.
    pub fn real(mut self, name: &str, v: f64) -> Self {
        self.ad.set_value(name, Value::Real(v));
        self
    }

    /// Set a boolean attribute.
    pub fn bool(mut self, name: &str, v: bool) -> Self {
        self.ad.set_value(name, Value::Bool(v));
        self
    }

    /// Set a byte quantity (displays as `50G` style).
    pub fn bytes(mut self, name: &str, bytes: f64) -> Self {
        self.ad.set_value(name, Value::Quantity { base: bytes, rate: false });
        self
    }

    /// Set a bandwidth quantity (displays as `75K/Sec` style).
    pub fn rate(mut self, name: &str, bytes_per_sec: f64) -> Self {
        self.ad
            .set_value(name, Value::Quantity { base: bytes_per_sec, rate: true });
        self
    }

    /// Set a list-of-strings attribute (e.g. `filesystem`).
    pub fn strings(mut self, name: &str, vs: &[&str]) -> Self {
        self.ad.set_value(
            name,
            Value::List(vs.iter().map(|s| Value::Str((*s).into())).collect()),
        );
        self
    }

    /// Set an attribute from ClassAd expression *text* (panics on parse
    /// error — use [`AdBuilder::try_expr`] for untrusted input).
    pub fn expr(mut self, name: &str, src: &str) -> Self {
        self.ad.set(
            name,
            parse_expr(src).unwrap_or_else(|e| panic!("bad expr {src:?}: {e}")),
        );
        self
    }

    /// Fallible variant of [`AdBuilder::expr`].
    pub fn try_expr(mut self, name: &str, src: &str) -> Result<Self, ParseError> {
        self.ad.set(name, parse_expr(src)?);
        Ok(self)
    }

    /// Set an already-built expression.
    pub fn set(mut self, name: &str, e: Expr) -> Self {
        self.ad.set(name, e);
        self
    }

    pub fn build(self) -> ClassAd {
        self.ad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::matchmaker::symmetric_match;
    use crate::classad::parser::parse_classad;

    #[test]
    fn builds_the_paper_storage_ad() {
        let built = AdBuilder::new()
            .str("hostname", "hugo.mcs.anl.gov")
            .str("volume", "/dev/sandbox")
            .bytes("availableSpace", 50.0 * 1024f64.powi(3))
            .rate("MaxRDBandwidth", 75.0 * 1024.0)
            .expr(
                "requirement",
                "other.reqdSpace < 10G && other.reqdRDBandwidth < 75K/Sec",
            )
            .build();
        let parsed = parse_classad(
            r#"hostname = "hugo.mcs.anl.gov";
               volume = "/dev/sandbox";
               availableSpace = 50G;
               MaxRDBandwidth = 75K/Sec;
               requirement = other.reqdSpace < 10G && other.reqdRDBandwidth < 75K/Sec;"#,
        )
        .unwrap();
        assert_eq!(built, parsed);
    }

    #[test]
    fn built_ads_match_like_parsed_ads() {
        let storage = AdBuilder::new()
            .bytes("availableSpace", 50.0 * 1024f64.powi(3))
            .rate("MaxRDBandwidth", 75.0 * 1024.0)
            .build();
        let request = AdBuilder::new()
            .bytes("reqdSpace", 5.0 * 1024f64.powi(3))
            .expr("requirement", "other.availableSpace > 5G")
            .expr("rank", "other.availableSpace")
            .build();
        assert!(symmetric_match(&request, &storage));
    }

    #[test]
    fn strings_list_and_member() {
        let ad = AdBuilder::new().strings("filesystem", &["ext3", "xfs"]).build();
        let req = AdBuilder::new()
            .expr("requirement", "member(\"xfs\", other.filesystem)")
            .build();
        assert!(symmetric_match(&req, &ad));
    }

    #[test]
    fn try_expr_reports_errors() {
        assert!(AdBuilder::new().try_expr("x", "1 +").is_err());
    }
}
