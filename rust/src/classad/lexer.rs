//! ClassAd lexer.
//!
//! Handles the classic token set plus the paper's unit-suffixed
//! quantities: `50G`, `75K/Sec` lex as single `Quantity` tokens (a
//! magnitude immediately followed by a K/M/G/T/P suffix, optionally
//! followed immediately by `/Sec`). `a / Sec` with spaces still lexes as
//! division by an identifier.

use thiserror::Error;

use crate::util::units::parse_quantity;

/// Lexical token.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Int(i64),
    Real(f64),
    Quantity { base: f64, rate: bool },
    Str(String),
    Ident(String),
    // punctuation / operators
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Semi,
    Assign,   // =
    Question, // ?
    Colon,    // :
    Dot,      // .
    OrOr,
    AndAnd,
    Pipe,
    Caret,
    Amp,
    EqEq,
    Ne,
    Is,   // =?=
    Isnt, // =!=
    Lt,
    Le,
    Gt,
    Ge,
    Shl,
    Shr,
    Ushr,
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    Bang,
    Tilde,
}

/// Lexer errors carry a byte offset for diagnostics.
#[derive(Debug, Error, PartialEq)]
pub enum LexError {
    #[error("unterminated string starting at byte {0}")]
    UnterminatedString(usize),
    #[error("bad number {1:?} at byte {0}")]
    BadNumber(usize, String),
    #[error("unexpected character {1:?} at byte {0}")]
    Unexpected(usize, char),
}

/// Tokenize `src` into a vector of tokens.
pub fn lex(src: &str) -> Result<Vec<Tok>, LexError> {
    let b = src.as_bytes();
    let mut i = 0usize;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i] as char;
        match c {
            ' ' | '\t' | '\r' | '\n' => i += 1,
            '/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            '/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                i += 2;
                while i + 1 < b.len() && !(b[i] == b'*' && b[i + 1] == b'/') {
                    i += 1;
                }
                i = (i + 2).min(b.len());
            }
            '"' => {
                let start = i;
                i += 1;
                let mut s = String::new();
                loop {
                    if i >= b.len() {
                        return Err(LexError::UnterminatedString(start));
                    }
                    match b[i] {
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\\' if i + 1 < b.len() => {
                            let e = b[i + 1] as char;
                            s.push(match e {
                                'n' => '\n',
                                't' => '\t',
                                '\\' => '\\',
                                '"' => '"',
                                other => other,
                            });
                            i += 2;
                        }
                        other => {
                            s.push(other as char);
                            i += 1;
                        }
                    }
                }
                out.push(Tok::Str(s));
            }
            '0'..='9' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'.') {
                    i += 1;
                }
                // exponent
                if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
                    let mut j = i + 1;
                    if j < b.len() && (b[j] == b'+' || b[j] == b'-') {
                        j += 1;
                    }
                    if j < b.len() && b[j].is_ascii_digit() {
                        i = j;
                        while i < b.len() && b[i].is_ascii_digit() {
                            i += 1;
                        }
                    }
                }
                let mag = &src[start..i];
                // Unit suffix? K/M/G/T/P (optionally B/iB), maybe /Sec.
                let suf_start = i;
                while i < b.len() && (b[i] as char).is_ascii_alphabetic() {
                    i += 1;
                }
                let suffix = &src[suf_start..i];
                if suffix.is_empty() {
                    // A bare number immediately followed by `/Sec` is a
                    // rate quantity (how non-integral rates unparse).
                    if src[i..].len() >= 4 && src[i..i + 4].eq_ignore_ascii_case("/sec") {
                        let base: f64 = mag
                            .parse()
                            .map_err(|_| LexError::BadNumber(start, mag.into()))?;
                        i += 4;
                        out.push(Tok::Quantity { base, rate: true });
                        continue;
                    }
                    let tok = if mag.contains('.') || mag.contains('e') || mag.contains('E') {
                        Tok::Real(
                            mag.parse()
                                .map_err(|_| LexError::BadNumber(start, mag.into()))?,
                        )
                    } else {
                        Tok::Int(
                            mag.parse()
                                .map_err(|_| LexError::BadNumber(start, mag.into()))?,
                        )
                    };
                    out.push(tok);
                } else {
                    // maybe "/Sec" immediately after (no whitespace)
                    let mut rate_len = 0;
                    if i + 3 < b.len() + 1 && src[i..].len() >= 4 {
                        let tail = &src[i..(i + 4).min(src.len())];
                        if tail.eq_ignore_ascii_case("/sec") {
                            rate_len = 4;
                        }
                    }
                    let full = &src[start..i + rate_len];
                    let (base, rate) = parse_quantity(full)
                        .map_err(|_| LexError::BadNumber(start, full.into()))?;
                    i += rate_len;
                    out.push(Tok::Quantity { base, rate });
                }
            }
            'a'..='z' | 'A'..='Z' | '_' => {
                let start = i;
                while i < b.len()
                    && ((b[i] as char).is_ascii_alphanumeric() || b[i] == b'_')
                {
                    i += 1;
                }
                out.push(Tok::Ident(src[start..i].to_string()));
            }
            '(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            '{' => {
                out.push(Tok::LBrace);
                i += 1;
            }
            '}' => {
                out.push(Tok::RBrace);
                i += 1;
            }
            '[' => {
                out.push(Tok::LBracket);
                i += 1;
            }
            ']' => {
                out.push(Tok::RBracket);
                i += 1;
            }
            ',' => {
                out.push(Tok::Comma);
                i += 1;
            }
            ';' => {
                out.push(Tok::Semi);
                i += 1;
            }
            '?' => {
                out.push(Tok::Question);
                i += 1;
            }
            ':' => {
                out.push(Tok::Colon);
                i += 1;
            }
            '.' => {
                out.push(Tok::Dot);
                i += 1;
            }
            '+' => {
                out.push(Tok::Plus);
                i += 1;
            }
            '-' => {
                out.push(Tok::Minus);
                i += 1;
            }
            '*' => {
                out.push(Tok::Star);
                i += 1;
            }
            '/' => {
                out.push(Tok::Slash);
                i += 1;
            }
            '%' => {
                out.push(Tok::Percent);
                i += 1;
            }
            '~' => {
                out.push(Tok::Tilde);
                i += 1;
            }
            '^' => {
                out.push(Tok::Caret);
                i += 1;
            }
            '|' => {
                if b.get(i + 1) == Some(&b'|') {
                    out.push(Tok::OrOr);
                    i += 2;
                } else {
                    out.push(Tok::Pipe);
                    i += 1;
                }
            }
            '&' => {
                if b.get(i + 1) == Some(&b'&') {
                    out.push(Tok::AndAnd);
                    i += 2;
                } else {
                    out.push(Tok::Amp);
                    i += 1;
                }
            }
            '!' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Tok::Ne);
                    i += 2;
                } else {
                    out.push(Tok::Bang);
                    i += 1;
                }
            }
            '=' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Tok::EqEq);
                    i += 2;
                } else if b.get(i + 1) == Some(&b'?') && b.get(i + 2) == Some(&b'=') {
                    out.push(Tok::Is);
                    i += 3;
                } else if b.get(i + 1) == Some(&b'!') && b.get(i + 2) == Some(&b'=') {
                    out.push(Tok::Isnt);
                    i += 3;
                } else {
                    out.push(Tok::Assign);
                    i += 1;
                }
            }
            '<' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Tok::Le);
                    i += 2;
                } else if b.get(i + 1) == Some(&b'<') {
                    out.push(Tok::Shl);
                    i += 2;
                } else {
                    out.push(Tok::Lt);
                    i += 1;
                }
            }
            '>' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push(Tok::Ge);
                    i += 2;
                } else if b.get(i + 1) == Some(&b'>') && b.get(i + 2) == Some(&b'>') {
                    out.push(Tok::Ushr);
                    i += 3;
                } else if b.get(i + 1) == Some(&b'>') {
                    out.push(Tok::Shr);
                    i += 2;
                } else {
                    out.push(Tok::Gt);
                    i += 1;
                }
            }
            other => return Err(LexError::Unexpected(i, other)),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_paper_storage_ad_tokens() {
        let toks = lex("availableSpace = 50G;").unwrap();
        assert_eq!(
            toks,
            vec![
                Tok::Ident("availableSpace".into()),
                Tok::Assign,
                Tok::Quantity { base: 50.0 * 1024f64.powi(3), rate: false },
                Tok::Semi,
            ]
        );
    }

    #[test]
    fn lexes_rate_quantity() {
        let toks = lex("MaxRDBandwidth = 75K/Sec;").unwrap();
        assert!(matches!(
            toks[2],
            Tok::Quantity { base, rate: true } if (base - 76800.0).abs() < 1e-9
        ));
    }

    #[test]
    fn rate_requires_adjacency() {
        // With whitespace, "/" is division and Sec an identifier.
        let toks = lex("5K / Sec").unwrap();
        assert_eq!(toks.len(), 3);
        assert!(matches!(toks[0], Tok::Quantity { rate: false, .. }));
        assert_eq!(toks[1], Tok::Slash);
        assert_eq!(toks[2], Tok::Ident("Sec".into()));
    }

    #[test]
    fn lexes_operators() {
        let toks = lex("a =?= b =!= c << 1 >> 2 >>> 3 <= >= != ==").unwrap();
        assert!(toks.contains(&Tok::Is));
        assert!(toks.contains(&Tok::Isnt));
        assert!(toks.contains(&Tok::Shl));
        assert!(toks.contains(&Tok::Shr));
        assert!(toks.contains(&Tok::Ushr));
        assert!(toks.contains(&Tok::Le));
        assert!(toks.contains(&Tok::Ge));
        assert!(toks.contains(&Tok::Ne));
        assert!(toks.contains(&Tok::EqEq));
    }

    #[test]
    fn lexes_strings_with_escapes() {
        let toks = lex(r#"host = "a\"b\n";"#).unwrap();
        assert_eq!(toks[2], Tok::Str("a\"b\n".into()));
    }

    #[test]
    fn unterminated_string_errors() {
        assert!(matches!(lex("\"abc"), Err(LexError::UnterminatedString(0))));
    }

    #[test]
    fn comments_skipped() {
        let toks = lex("a // comment\n= /* inline */ 1").unwrap();
        assert_eq!(toks.len(), 3);
    }

    #[test]
    fn reals_and_exponents() {
        let toks = lex("1.5 2e3 7").unwrap();
        assert_eq!(
            toks,
            vec![Tok::Real(1.5), Tok::Real(2000.0), Tok::Int(7)]
        );
    }

    #[test]
    fn unexpected_char_reports_position() {
        assert_eq!(lex("a @ b"), Err(LexError::Unexpected(2, '@')));
    }
}
