//! Recursive-descent / precedence-climbing parser for ClassAds.
//!
//! Accepts both the paper's bare form
//!
//! ```text
//! hostname = "hugo.mcs.anl.gov";
//! requirement = other.reqdSpace < 10G;
//! ```
//!
//! and the bracketed new-ClassAd form `[ a = 1; b = a + 1 ]`.

use thiserror::Error;

use super::ast::{BinOp, ClassAd, Expr, Scope, UnOp};
use super::lexer::{lex, LexError, Tok};
use super::value::Value;

/// Parse errors.
#[derive(Debug, Error, PartialEq)]
pub enum ParseError {
    #[error(transparent)]
    Lex(#[from] LexError),
    #[error("unexpected end of input")]
    Eof,
    #[error("unexpected token {0:?} (expected {1})")]
    Unexpected(String, &'static str),
    #[error("trailing tokens after expression")]
    Trailing,
    #[error(
        "ad would intern {fresh} new attribute names (budget {budget}) — \
         rejected to keep the global intern table bounded"
    )]
    AttrBudget { fresh: usize, budget: usize },
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, want: &Tok, what: &'static str) -> Result<(), ParseError> {
        match self.next() {
            Some(ref t) if t == want => Ok(()),
            Some(t) => Err(ParseError::Unexpected(format!("{t:?}"), what)),
            None => Err(ParseError::Eof),
        }
    }

    fn bin_op(tok: &Tok) -> Option<BinOp> {
        Some(match tok {
            Tok::OrOr => BinOp::Or,
            Tok::AndAnd => BinOp::And,
            Tok::Pipe => BinOp::BitOr,
            Tok::Caret => BinOp::BitXor,
            Tok::Amp => BinOp::BitAnd,
            Tok::EqEq => BinOp::Eq,
            Tok::Ne => BinOp::Ne,
            Tok::Is => BinOp::Is,
            Tok::Isnt => BinOp::Isnt,
            Tok::Lt => BinOp::Lt,
            Tok::Le => BinOp::Le,
            Tok::Gt => BinOp::Gt,
            Tok::Ge => BinOp::Ge,
            Tok::Shl => BinOp::Shl,
            Tok::Shr => BinOp::Shr,
            Tok::Ushr => BinOp::Ushr,
            Tok::Plus => BinOp::Add,
            Tok::Minus => BinOp::Sub,
            Tok::Star => BinOp::Mul,
            Tok::Slash => BinOp::Div,
            Tok::Percent => BinOp::Mod,
            _ => return None,
        })
    }

    /// expr := ternary
    fn expr(&mut self) -> Result<Expr, ParseError> {
        let cond = self.binary(1)?;
        if self.peek() == Some(&Tok::Question) {
            self.next();
            let t = self.expr()?;
            self.expect(&Tok::Colon, "':' in conditional")?;
            let f = self.expr()?;
            Ok(Expr::Cond(Box::new(cond), Box::new(t), Box::new(f)))
        } else {
            Ok(cond)
        }
    }

    /// Precedence climbing over binary operators.
    fn binary(&mut self, min_prec: u8) -> Result<Expr, ParseError> {
        let mut lhs = self.unary()?;
        while let Some(op) = self.peek().and_then(Self::bin_op) {
            let p = op.precedence();
            if p < min_prec {
                break;
            }
            self.next();
            let rhs = self.binary(p + 1)?; // left associative
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn unary(&mut self) -> Result<Expr, ParseError> {
        match self.peek() {
            Some(Tok::Bang) => {
                self.next();
                Ok(Expr::Unary(UnOp::Not, Box::new(self.unary()?)))
            }
            Some(Tok::Minus) => {
                self.next();
                // Constant-fold negation of numeric literals so that
                // `-5` parses as the literal -5 (unparse fixpoint).
                Ok(match self.unary()? {
                    Expr::Lit(Value::Int(i)) => Expr::Lit(Value::Int(-i)),
                    Expr::Lit(Value::Real(r)) => Expr::Lit(Value::Real(-r)),
                    Expr::Lit(Value::Quantity { base, rate }) => {
                        Expr::Lit(Value::Quantity { base: -base, rate })
                    }
                    e => Expr::Unary(UnOp::Neg, Box::new(e)),
                })
            }
            Some(Tok::Plus) => {
                self.next();
                self.unary()
            }
            Some(Tok::Tilde) => {
                self.next();
                Ok(Expr::Unary(UnOp::BitNot, Box::new(self.unary()?)))
            }
            _ => self.primary(),
        }
    }

    fn primary(&mut self) -> Result<Expr, ParseError> {
        let tok = self.next().ok_or(ParseError::Eof)?;
        match tok {
            Tok::Int(i) => Ok(Expr::Lit(Value::Int(i))),
            Tok::Real(r) => Ok(Expr::Lit(Value::Real(r))),
            Tok::Quantity { base, rate } => Ok(Expr::Lit(Value::Quantity { base, rate })),
            Tok::Str(s) => Ok(Expr::Lit(Value::Str(s))),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(&Tok::RParen, "')'")?;
                Ok(e)
            }
            Tok::LBrace => {
                let mut items = Vec::new();
                if self.peek() != Some(&Tok::RBrace) {
                    loop {
                        items.push(self.expr()?);
                        match self.peek() {
                            Some(Tok::Comma) => {
                                self.next();
                            }
                            _ => break,
                        }
                    }
                }
                self.expect(&Tok::RBrace, "'}'")?;
                Ok(Expr::List(items))
            }
            Tok::Ident(name) => self.ident_tail(name),
            other => Err(ParseError::Unexpected(format!("{other:?}"), "expression")),
        }
    }

    /// Identifier followed by optional `.attr` scope access or a call.
    fn ident_tail(&mut self, name: String) -> Result<Expr, ParseError> {
        let lower = name.to_ascii_lowercase();
        // Keywords.
        match lower.as_str() {
            "true" => return Ok(Expr::Lit(Value::Bool(true))),
            "false" => return Ok(Expr::Lit(Value::Bool(false))),
            "undefined" => return Ok(Expr::Lit(Value::Undefined)),
            "error" => return Ok(Expr::Lit(Value::Error)),
            _ => {}
        }
        // Scope prefix: other.x / target.x / self.x / my.x
        if self.peek() == Some(&Tok::Dot) {
            let scope = match lower.as_str() {
                "other" | "target" => Some(Scope::Other),
                "self" | "my" => Some(Scope::My),
                _ => None,
            };
            if let Some(scope) = scope {
                self.next(); // dot
                match self.next() {
                    Some(Tok::Ident(attr)) => return Ok(Expr::Attr(scope, attr.into())),
                    Some(t) => {
                        return Err(ParseError::Unexpected(
                            format!("{t:?}"),
                            "attribute name after scope",
                        ))
                    }
                    None => return Err(ParseError::Eof),
                }
            }
            // Unknown scope: treat `a.b` as attribute "a.b" (LDAP-ish
            // dotted names appear in converted LDIF ads).
            self.next();
            match self.next() {
                Some(Tok::Ident(attr)) => {
                    return Ok(Expr::Attr(Scope::Default, format!("{name}.{attr}").into()))
                }
                Some(t) => {
                    return Err(ParseError::Unexpected(format!("{t:?}"), "attribute name"))
                }
                None => return Err(ParseError::Eof),
            }
        }
        // Call?
        if self.peek() == Some(&Tok::LParen) {
            self.next();
            let mut args = Vec::new();
            if self.peek() != Some(&Tok::RParen) {
                loop {
                    args.push(self.expr()?);
                    match self.peek() {
                        Some(Tok::Comma) => {
                            self.next();
                        }
                        _ => break,
                    }
                }
            }
            self.expect(&Tok::RParen, "')' after call arguments")?;
            return Ok(Expr::Call(lower, args));
        }
        Ok(Expr::Attr(Scope::Default, name.into()))
    }

    /// classad := '[' bindings ']' | bindings
    fn classad(&mut self) -> Result<ClassAd, ParseError> {
        let bracketed = self.peek() == Some(&Tok::LBracket);
        if bracketed {
            self.next();
        }
        let mut ad = ClassAd::new();
        loop {
            match self.peek() {
                None => break,
                Some(Tok::RBracket) if bracketed => {
                    self.next();
                    break;
                }
                Some(Tok::Semi) => {
                    self.next();
                    continue;
                }
                Some(Tok::Ident(_)) => {
                    let name = match self.next() {
                        Some(Tok::Ident(n)) => n,
                        _ => unreachable!(),
                    };
                    self.expect(&Tok::Assign, "'=' after attribute name")?;
                    let e = self.expr()?;
                    ad.set(name, e);
                    match self.peek() {
                        Some(Tok::Semi) => {
                            self.next();
                        }
                        Some(Tok::RBracket) if bracketed => {}
                        None => {}
                        Some(t) => {
                            return Err(ParseError::Unexpected(
                                format!("{t:?}"),
                                "';' between bindings",
                            ))
                        }
                    }
                }
                Some(t) => {
                    return Err(ParseError::Unexpected(
                        format!("{t:?}"),
                        "attribute binding",
                    ))
                }
            }
        }
        Ok(ad)
    }
}

/// Parse a pre-lexed ClassAd token stream — the shared tail of both
/// the trusted and the budget-gated entry points.
fn parse_classad_toks(toks: Vec<Tok>) -> Result<ClassAd, ParseError> {
    let mut p = Parser { toks, pos: 0 };
    let ad = p.classad()?;
    if p.pos != p.toks.len() {
        return Err(ParseError::Trailing);
    }
    Ok(ad)
}

/// Parse a full ClassAd (bare `a = e; ...` or bracketed `[a = e; ...]`).
pub fn parse_classad(src: &str) -> Result<ClassAd, ParseError> {
    parse_classad_toks(lex(src)?)
}

/// Parse a ClassAd from an *untrusted* source, rejecting it — before
/// any interning happens — if its identifiers would add more than
/// `max_new_names` entries to the global attribute-name table
/// ([`super::intern`]). Interned names are leaked by design, so an
/// attacker feeding generated attribute names through an unbounded
/// parse would grow the table forever; the pre-scan walks the token
/// stream and counts distinct identifiers that [`Sym::lookup`] has
/// never seen. The count is conservative (scope words and builtin
/// function names an ad mentions first also count), so budgets should
/// be generous — see `broker::parse_request_ad` for the boundary
/// default. Beyond the per-ad budget, a process-wide cap
/// ([`super::intern::UNTRUSTED_TABLE_CAP`]) bounds what untrusted
/// input may ever grow the table to — a *stream* of budget-sized
/// hostile ads is rejected once the cap is reached, while ads using
/// only known vocabulary keep parsing forever.
pub fn parse_classad_bounded(
    src: &str,
    max_new_names: usize,
) -> Result<ClassAd, ParseError> {
    use super::intern::Sym;
    let toks = lex(src)?;
    let mut fresh: std::collections::HashSet<String> = std::collections::HashSet::new();
    for t in &toks {
        if let Tok::Ident(name) = t {
            if Sym::lookup(name).is_none() {
                fresh.insert(name.to_ascii_lowercase());
            }
        }
    }
    if fresh.len() > max_new_names {
        return Err(ParseError::AttrBudget { fresh: fresh.len(), budget: max_new_names });
    }
    // Per-ad budgets alone cannot bound the table: a stream of hostile
    // budget-sized ads would still leak linearly. The process-wide cap
    // (`intern::UNTRUSTED_TABLE_CAP`) closes that; ads whose names are
    // all already known always pass (fresh is empty).
    let have = super::intern::table_len();
    if !fresh.is_empty() && have + fresh.len() > super::intern::UNTRUSTED_TABLE_CAP {
        return Err(ParseError::AttrBudget {
            fresh: fresh.len(),
            budget: super::intern::UNTRUSTED_TABLE_CAP.saturating_sub(have),
        });
    }
    parse_classad_toks(toks)
}

/// Parse a single expression.
pub fn parse_expr(src: &str) -> Result<Expr, ParseError> {
    let mut p = Parser { toks: lex(src)?, pos: 0 };
    let e = p.expr()?;
    if p.pos != p.toks.len() {
        return Err(ParseError::Trailing);
    }
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The storage ad exactly as printed in §4 of the paper.
    pub const PAPER_STORAGE_AD: &str = r#"
        hostname = "hugo.mcs.anl.gov";
        volume = "/dev/sandbox";
        availableSpace = 50G;
        MaxRDBandwidth = 75K/Sec;
        requirement = other.reqdSpace < 10G
            && other.reqdRDBandwidth < 75K/Sec;
    "#;

    /// The request ad exactly as printed in §5.2 of the paper.
    pub const PAPER_REQUEST_AD: &str = r#"
        hostname = "comet.xyz.com";
        reqdSpace = 5G;
        reqdRDBandwidth = 50K/Sec;
        rank = other.availableSpace;
        requirement = other.availableSpace >
            5G && other.MaxRDBandwidth >
            50K/Sec;
    "#;

    #[test]
    fn parses_paper_storage_ad() {
        let ad = parse_classad(PAPER_STORAGE_AD).unwrap();
        assert_eq!(ad.len(), 5);
        assert_eq!(ad.string("hostname").unwrap(), "hugo.mcs.anl.gov");
        assert_eq!(ad.number("availableSpace").unwrap(), 50.0 * 1024f64.powi(3));
        assert!(ad.get("requirement").is_some());
    }

    #[test]
    fn parses_paper_request_ad() {
        let ad = parse_classad(PAPER_REQUEST_AD).unwrap();
        assert_eq!(ad.number("reqdRDBandwidth").unwrap(), 50.0 * 1024.0);
        assert_eq!(
            ad.get("rank").unwrap(),
            &Expr::Attr(Scope::Other, "availableSpace".into())
        );
    }

    #[test]
    fn parses_bracketed_form() {
        let ad = parse_classad("[ a = 1; b = a + 1 ]").unwrap();
        assert_eq!(ad.len(), 2);
    }

    #[test]
    fn precedence_and_over_or() {
        let e = parse_expr("a || b && c").unwrap();
        assert_eq!(e.to_string(), "a || b && c");
        match e {
            Expr::Binary(BinOp::Or, _, rhs) => {
                assert!(matches!(*rhs, Expr::Binary(BinOp::And, _, _)));
            }
            other => panic!("bad tree {other:?}"),
        }
    }

    #[test]
    fn ternary_parses() {
        let e = parse_expr("a > 1 ? \"big\" : \"small\"").unwrap();
        assert!(matches!(e, Expr::Cond(_, _, _)));
    }

    #[test]
    fn call_and_list_parse() {
        let e = parse_expr("member(\"ext3\", {\"ext3\", \"xfs\"})").unwrap();
        match e {
            Expr::Call(name, args) => {
                assert_eq!(name, "member");
                assert_eq!(args.len(), 2);
                assert!(matches!(args[1], Expr::List(_)));
            }
            other => panic!("bad parse {other:?}"),
        }
    }

    #[test]
    fn scope_forms() {
        assert_eq!(
            parse_expr("other.x").unwrap(),
            Expr::Attr(Scope::Other, "x".into())
        );
        assert_eq!(
            parse_expr("target.x").unwrap(),
            Expr::Attr(Scope::Other, "x".into())
        );
        assert_eq!(parse_expr("self.x").unwrap(), Expr::Attr(Scope::My, "x".into()));
        assert_eq!(parse_expr("my.x").unwrap(), Expr::Attr(Scope::My, "x".into()));
    }

    #[test]
    fn keywords_case_insensitive() {
        assert_eq!(parse_expr("TRUE").unwrap(), Expr::Lit(Value::Bool(true)));
        assert_eq!(parse_expr("Undefined").unwrap(), Expr::Lit(Value::Undefined));
        assert_eq!(parse_expr("error").unwrap(), Expr::Lit(Value::Error));
    }

    #[test]
    fn unparse_reparse_fixpoint() {
        for src in [PAPER_STORAGE_AD, PAPER_REQUEST_AD] {
            let ad = parse_classad(src).unwrap();
            let re = parse_classad(&ad.to_string()).unwrap();
            assert_eq!(ad, re);
        }
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse_classad("a = ;").is_err());
        assert!(parse_expr("1 +").is_err());
        assert!(parse_expr("(1").is_err());
        assert!(matches!(parse_expr("1 2"), Err(ParseError::Trailing)));
    }

    #[test]
    fn bounded_parse_rejects_name_floods_before_interning() {
        use super::super::intern;
        // An adversarial ad full of never-seen generated names.
        let flood: String = (0..40)
            .map(|i| format!("bounded_flood_attr_{i} = {i};\n"))
            .collect();
        let before = intern::table_len();
        let err = parse_classad_bounded(&flood, 8).unwrap_err();
        assert!(matches!(err, ParseError::AttrBudget { fresh: 40, budget: 8 }));
        // The rejection happened BEFORE interning: none of the flood's
        // names entered the table. (Checked per name, not via
        // `table_len`, because parallel tests intern concurrently.)
        assert!(intern::Sym::lookup("bounded_flood_attr_0").is_none());
        assert!(intern::Sym::lookup("bounded_flood_attr_39").is_none());
        // Within budget the same source parses fine (and only then
        // interns its names).
        let ad = parse_classad_bounded(&flood, 64).unwrap();
        assert_eq!(ad.len(), 40);
        assert!(intern::Sym::lookup("bounded_flood_attr_0").is_some());
        assert!(intern::table_len() >= before + 40);
        // Re-parsing is free: every name is now known, so even a
        // budget of 0 admits the ad.
        assert!(parse_classad_bounded(&flood, 0).is_ok());
    }

    #[test]
    fn bounded_parse_enforces_the_process_wide_cap() {
        use super::super::intern;
        let room = intern::UNTRUSTED_TABLE_CAP.saturating_sub(intern::table_len());
        // More fresh names than untrusted input may EVER intern, with a
        // per-ad budget that would allow them — the global cap must
        // reject what the per-ad gate admits.
        let flood: String = (0..=room)
            .map(|i| format!("global_cap_flood_{i} = {i};\n"))
            .collect();
        let err = parse_classad_bounded(&flood, usize::MAX).unwrap_err();
        assert!(matches!(err, ParseError::AttrBudget { .. }));
        // Rejected before interning: the table did not absorb it.
        assert!(intern::Sym::lookup("global_cap_flood_0").is_none());
    }

    #[test]
    fn bounded_parse_accepts_known_vocabulary() {
        // Warm the vocabulary through the unbounded path (the GRIS
        // schema is trusted), then the paper's request ad must pass
        // with a tiny budget.
        parse_classad(PAPER_REQUEST_AD).unwrap();
        let ad = parse_classad_bounded(PAPER_REQUEST_AD, 0).unwrap();
        assert!(ad.get("rank").is_some());
    }

    #[test]
    fn bounded_parse_still_reports_syntax_errors() {
        assert!(parse_classad_bounded("a = ;", 64).is_err());
    }
}
