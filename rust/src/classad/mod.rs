//! Condor Classified Advertisements (ClassAds), reimplemented in Rust.
//!
//! The paper (§4) uses ClassAds to describe storage resource
//! capabilities/policies and application requirements, matched and
//! ranked by the Condor matchmaking mechanism [Raman et al., HPDC'98].
//! This module is a faithful implementation of the *classic* ClassAd
//! language as those papers (and the paper's own examples) use it:
//!
//! * attribute = expression lists, e.g.
//!   `availableSpace = 50G; requirement = other.reqdSpace < 10G;`
//! * three-valued logic (`TRUE`/`FALSE`/`UNDEFINED`, plus `ERROR`),
//! * cross-ad references through `other.attr` (and `self`/`my`),
//! * unit-suffixed quantities (`50G`, `75K/Sec`) exactly as written in
//!   the paper's example ads,
//! * `requirements` matching (symmetric) and `rank`-based ordering,
//! * a library of builtin functions (string, numeric, type-test,
//!   list membership, regexp).
//!
//! Submodules:
//! * [`lexer`] / [`parser`] — text form to AST,
//! * [`ast`] — expressions and the [`ClassAd`](ast::ClassAd) record
//!   (attributes indexed by interned symbol),
//! * [`intern`] — the global attribute-name interner ([`Sym`]),
//! * [`value`] — runtime values and three-valued logic,
//! * [`eval`] — the evaluator (with `other`-scope resolution and an
//!   allocation-free cycle guard),
//! * [`matchmaker`] — per-pair symmetric match + rank,
//! * [`compile`] — [`CompiledMatch`], the compile-once / match-many
//!   engine behind the broker's Match phase,
//! * [`program`] — the bytecode backend: `requirements`/`rank`
//!   flattened to a postfix [`Program`](program::Program) run by a
//!   stack VM over a dense [`CandidateTable`](program::CandidateTable)
//!   (the tree-walker in [`eval`] stays the reference evaluator),
//! * [`builder`] — ergonomic programmatic ad construction.

pub mod ast;
pub mod builder;
pub mod compile;
pub mod eval;
pub mod intern;
pub mod lexer;
pub mod matchmaker;
pub mod parser;
pub mod program;
pub mod value;

pub use ast::{AttrName, ClassAd, Expr};
pub use builder::AdBuilder;
pub use compile::CompiledMatch;
pub use program::{CandidateTable, Program, VmScratch};
pub use eval::{eval, eval_in_match, EvalCtx};
pub use intern::Sym;
pub use matchmaker::{match_ads, rank_candidates, rank_of, symmetric_match, Match};
pub use parser::{parse_classad, parse_classad_bounded, parse_expr};
pub use value::Value;
