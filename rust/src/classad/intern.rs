//! Global attribute-name interner.
//!
//! ClassAd attribute names are case-insensitive and drawn from a small
//! vocabulary (the GRIS schema plus whatever a request ad declares), so
//! every name is lowercased **once** and mapped to a dense [`Sym`]
//! handle. Ads index their attributes by `Sym`, the evaluator's cycle
//! guard stores `Sym` frames, and [`super::compile::CompiledMatch`]
//! pre-binds attribute references to symbols — the match-many hot path
//! never lowercases or allocates a key string again.
//!
//! Interned names are leaked (`&'static str`): the table only grows,
//! and it is bounded by the number of *distinct* attribute names the
//! process ever sees, which for this workload is tens of entries.

use std::collections::HashMap;
use std::sync::RwLock;

use once_cell::sync::Lazy;

/// An interned, lowercased attribute name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Sym(u32);

struct Interner {
    map: HashMap<&'static str, u32>,
    names: Vec<&'static str>,
}

static TABLE: Lazy<RwLock<Interner>> =
    Lazy::new(|| RwLock::new(Interner { map: HashMap::new(), names: Vec::new() }));

fn has_upper(name: &str) -> bool {
    name.bytes().any(|b| b.is_ascii_uppercase())
}

impl Sym {
    /// Sentinel for uninitialized slots in fixed-size frame arrays;
    /// never equal to an interned symbol. `as_str` must not be called
    /// on it.
    pub(crate) const DUMMY: Sym = Sym(u32::MAX);

    /// Intern `name` (case-insensitively), allocating a table slot on
    /// first sight. Already-lowercase names take the read-lock fast
    /// path without allocating.
    pub fn intern(name: &str) -> Sym {
        if !has_upper(name) {
            if let Some(&id) = TABLE.read().unwrap().map.get(name) {
                return Sym(id);
            }
            return Self::insert(name.to_string());
        }
        let lower = name.to_ascii_lowercase();
        if let Some(&id) = TABLE.read().unwrap().map.get(lower.as_str()) {
            return Sym(id);
        }
        Self::insert(lower)
    }

    fn insert(lower: String) -> Sym {
        let mut t = TABLE.write().unwrap();
        // Re-check under the write lock (another thread may have won).
        if let Some(&id) = t.map.get(lower.as_str()) {
            return Sym(id);
        }
        let leaked: &'static str = Box::leak(lower.into_boxed_str());
        let id = t.names.len() as u32;
        t.names.push(leaked);
        t.map.insert(leaked, id);
        Sym(id)
    }

    /// Look `name` up without inserting. `None` means the name was
    /// never interned anywhere — so no ad can contain it either.
    pub fn lookup(name: &str) -> Option<Sym> {
        let t = TABLE.read().unwrap();
        if !has_upper(name) {
            return t.map.get(name).map(|&id| Sym(id));
        }
        let lower = name.to_ascii_lowercase();
        t.map.get(lower.as_str()).map(|&id| Sym(id))
    }

    /// The canonical (lowercased) spelling.
    pub fn as_str(self) -> &'static str {
        TABLE.read().unwrap().names[self.0 as usize]
    }

    /// The dense table index backing this symbol — stable for the
    /// process lifetime, identical for every case-spelling of the same
    /// name. Dense consumers (the bytecode compiler's candidate-table
    /// columns, debug dumps) key on this instead of re-hashing the
    /// name.
    pub fn id(self) -> u32 {
        self.0
    }
}

/// Number of distinct names interned so far — the table's (leaked)
/// footprint. Boundary code uses this plus [`Sym::lookup`] to reject
/// untrusted ads that would grow the table past a budget *before* any
/// interning happens (see `classad::parse_classad_bounded`).
pub fn table_len() -> usize {
    TABLE.read().unwrap().names.len()
}

/// Process-wide soft cap on what *untrusted* input may grow the table
/// to: `classad::parse_classad_bounded` refuses ads whose new names
/// would push [`table_len`] past this, so a stream of hostile
/// budget-sized ads cannot leak memory linearly forever — per-ad
/// budgets alone would. Trusted paths (`parse_classad`, programmatic
/// [`Sym::intern`]) are not gated; "soft" because the check races
/// benignly with concurrent interning. Orders of magnitude above the
/// GRIS schema + request vocabulary (tens of names).
pub const UNTRUSTED_TABLE_CAP: usize = 4096;

impl std::fmt::Display for Sym {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interning_is_case_insensitive() {
        let a = Sym::intern("AvailableSpace");
        let b = Sym::intern("availablespace");
        let c = Sym::intern("AVAILABLESPACE");
        assert_eq!(a, b);
        assert_eq!(b, c);
        assert_eq!(a.as_str(), "availablespace");
    }

    #[test]
    fn lookup_does_not_insert() {
        assert_eq!(Sym::lookup("never-seen-attr-xyzzy"), None);
        let s = Sym::intern("never-seen-attr-xyzzy");
        assert_eq!(Sym::lookup("NEVER-SEEN-ATTR-XYZZY"), Some(s));
    }

    #[test]
    fn distinct_names_distinct_symbols() {
        assert_ne!(Sym::intern("reqdspace"), Sym::intern("reqdrdbandwidth"));
    }

    #[test]
    fn ids_are_stable_across_spellings() {
        assert_eq!(Sym::intern("MaxRDBandwidth").id(), Sym::intern("maxrdbandwidth").id());
        assert_ne!(Sym::intern("id-test-a").id(), Sym::intern("id-test-b").id());
    }

    #[test]
    fn concurrent_interning_converges() {
        // 8 threads race to intern the same 10 names; every thread must
        // observe the same symbol per name.
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(|| {
                    (0..10)
                        .map(|j| Sym::intern(&format!("race-attr-{j}")))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        let rows: Vec<Vec<Sym>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for row in &rows[1..] {
            assert_eq!(row, &rows[0]);
        }
    }
}
