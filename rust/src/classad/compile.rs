//! Compile-once / match-many matchmaking.
//!
//! The broker's Match phase evaluates one request ad against *every*
//! replica site's ad on every selection (paper §5.1.2 step 2). The
//! per-pair entry points ([`super::matchmaker`]) re-resolve the
//! `requirements`/`rank` attributes of the request by name for each
//! candidate; [`CompiledMatch`] hoists that work out of the loop:
//!
//! * the request's `requirements` and `rank` expressions are fetched
//!   once and **constant-folded** (literal-only subtrees collapse to a
//!   single literal),
//! * the `requirements`/`requirement` attribute symbols are pre-interned,
//!   so a candidate's own policy is found with one integer-keyed probe,
//! * matching and ranking run as a **single fused pass**: each side's
//!   requirements are evaluated at most once per candidate and rank
//!   evaluation is skipped entirely for non-matches.
//!
//! Results are bit-identical to the per-pair path — the same evaluator
//! runs underneath (see `it_match_parity`).

use once_cell::sync::Lazy;

use super::ast::{ClassAd, Expr};
use super::eval::{eval, EvalCtx};
use super::intern::Sym;
use super::matchmaker::Match;
use super::program::{CandidateTable, Program, VmScratch};
use super::value::Value;

/// Pre-interned requirements spellings, in lookup-preference order
/// (Condor's `requirements`, then the paper's `requirement`).
static REQUIREMENT_SYMS: Lazy<[Sym; 2]> =
    Lazy::new(|| [Sym::intern("requirements"), Sym::intern("requirement")]);

static RANK_SYM: Lazy<Sym> = Lazy::new(|| Sym::intern("rank"));

/// A request ad compiled for repeated matchmaking.
#[derive(Debug, Clone)]
pub struct CompiledMatch {
    request: ClassAd,
    /// The request's requirements expression, constant-folded.
    /// `None` = the ad publishes none = always willing.
    req_requirements: Option<Expr>,
    /// The request's rank expression, constant-folded. `None` ranks 0.
    req_rank: Option<Expr>,
    /// The same two expressions lowered to postfix bytecode
    /// ([`super::program`]); the folded trees above stay the reference
    /// evaluator the VM is pinned against.
    program: Program,
}

impl CompiledMatch {
    /// Compile `request` (the ad is snapshotted; later mutations of the
    /// original do not affect the handle).
    pub fn compile(request: &ClassAd) -> CompiledMatch {
        let req_requirements = requirements_expr(request).map(fold);
        let req_rank = request.get_sym(*RANK_SYM).map(fold);
        let program =
            Program::compile(request, req_requirements.as_ref(), req_rank.as_ref());
        CompiledMatch { request: request.clone(), req_requirements, req_rank, program }
    }

    pub fn request(&self) -> &ClassAd {
        &self.request
    }

    /// The bytecode backend (used by the broker to size and fill the
    /// batch [`CandidateTable`]).
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// Symmetric two-way match against one candidate (both sides'
    /// requirements must evaluate to TRUE, as in the per-pair
    /// [`super::matchmaker::symmetric_match`]).
    pub fn matches(&self, candidate: &ClassAd) -> bool {
        self.request_side_holds(candidate) && candidate_side_holds(candidate, &self.request)
    }

    /// The request's rank of `candidate` (non-numeric collapses to 0.0).
    pub fn rank(&self, candidate: &ClassAd) -> f64 {
        match &self.req_rank {
            None => 0.0,
            Some(e) => eval(EvalCtx::matched(&self.request, candidate), e)
                .as_number()
                .unwrap_or(0.0),
        }
    }

    fn request_side_holds(&self, candidate: &ClassAd) -> bool {
        match &self.req_requirements {
            None => true,
            Some(e) => matches!(
                eval(EvalCtx::matched(&self.request, candidate), e),
                Value::Bool(true)
            ),
        }
    }

    /// [`CompiledMatch::matches`] through the bytecode VM: the request
    /// side runs the compiled program, the candidate's own requirements
    /// run the shared tree-walk (they are the candidate's expression,
    /// unknown at compile time). Bit-identical to `matches`.
    pub fn matches_vm(&self, candidate: &ClassAd, vm: &mut VmScratch) -> bool {
        self.program.holds(&self.request, candidate, vm)
            && candidate_side_holds(candidate, &self.request)
    }

    /// [`CompiledMatch::rank`] through the bytecode VM.
    pub fn rank_vm(&self, candidate: &ClassAd, vm: &mut VmScratch) -> f64 {
        self.program.rank(&self.request, candidate, vm)
    }

    /// [`CompiledMatch::matches_vm`] reading candidate attributes from
    /// `table` row `row` instead of probing the ad.
    pub fn matches_vm_row(
        &self,
        candidate: &ClassAd,
        table: &CandidateTable,
        row: usize,
        vm: &mut VmScratch,
    ) -> bool {
        self.program.holds_row(&self.request, candidate, table, row, vm)
            && candidate_side_holds(candidate, &self.request)
    }

    /// The fused Match-phase pass: per-candidate match flags plus the
    /// ranked survivors, best first (ties broken by candidate index —
    /// the deterministic catalog-order tiebreak the broker relies on).
    pub fn match_and_rank<'a, I>(&self, candidates: I) -> (Vec<bool>, Vec<Match>)
    where
        I: IntoIterator<Item = &'a ClassAd>,
    {
        let mut flags = Vec::new();
        let mut out = Vec::new();
        self.match_and_rank_into(candidates, &mut flags, &mut out);
        (flags, out)
    }

    /// [`CompiledMatch::match_and_rank`] into caller-owned buffers
    /// (cleared first) — the allocation-free form the broker's
    /// `SelectScratch` reuses across selections.
    pub fn match_and_rank_into<'a, I>(
        &self,
        candidates: I,
        flags: &mut Vec<bool>,
        out: &mut Vec<Match>,
    ) where
        I: IntoIterator<Item = &'a ClassAd>,
    {
        flags.clear();
        out.clear();
        for (index, c) in candidates.into_iter().enumerate() {
            let ok = self.matches(c);
            flags.push(ok);
            if ok {
                out.push(Match { index, rank: self.rank(c) });
            }
        }
        sort_matches(out);
    }

    /// The fused pass on the bytecode VM, optionally down a
    /// [`CandidateTable`] (whose rows must mirror `candidates` in
    /// order). Buffers are cleared first and reused; results are
    /// bit-identical to [`CompiledMatch::match_and_rank`].
    pub fn match_and_rank_vm_into<'a, I>(
        &self,
        candidates: I,
        table: Option<&CandidateTable>,
        flags: &mut Vec<bool>,
        out: &mut Vec<Match>,
        vm: &mut VmScratch,
    ) where
        I: IntoIterator<Item = &'a ClassAd>,
    {
        flags.clear();
        out.clear();
        for (index, c) in candidates.into_iter().enumerate() {
            let ok = match table {
                Some(t) => self.matches_vm_row(c, t, index, vm),
                None => self.matches_vm(c, vm),
            };
            flags.push(ok);
            if ok {
                let rank = match table {
                    Some(t) => self.program.rank_row(&self.request, c, t, index, vm),
                    None => self.rank_vm(c, vm),
                };
                out.push(Match { index, rank });
            }
        }
        sort_matches(out);
    }

    /// Ranked survivors only (the [`super::matchmaker::rank_candidates`]
    /// contract).
    pub fn rank_candidates(&self, candidates: &[ClassAd]) -> Vec<Match> {
        self.match_and_rank(candidates.iter()).1
    }
}

/// Order best-rank-first, stable on candidate index for equal ranks.
pub(crate) fn sort_matches(ms: &mut [Match]) {
    ms.sort_by(|a, b| {
        b.rank
            .partial_cmp(&a.rank)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.index.cmp(&b.index))
    });
}

/// The candidate's own requirements, looked up by pre-interned symbol.
fn candidate_side_holds(candidate: &ClassAd, request: &ClassAd) -> bool {
    for &sym in REQUIREMENT_SYMS.iter() {
        if let Some(e) = candidate.get_sym(sym) {
            return matches!(eval(EvalCtx::matched(candidate, request), e), Value::Bool(true));
        }
    }
    true
}

fn requirements_expr(ad: &ClassAd) -> Option<&Expr> {
    REQUIREMENT_SYMS.iter().find_map(|&sym| ad.get_sym(sym))
}

/// Bottom-up constant folding: a node whose children are all literals
/// evaluates to the same value for every candidate, so it collapses to
/// that value now. Attribute references (any scope) block folding, and
/// partial boolean folds are deliberately not attempted — `TRUE && x`
/// is *not* equivalent to `x` under three-valued logic when `x` is
/// non-boolean.
pub fn fold(e: &Expr) -> Expr {
    static EMPTY: Lazy<ClassAd> = Lazy::new(ClassAd::new);
    match e {
        Expr::Lit(_) | Expr::Attr(..) => e.clone(),
        Expr::Unary(op, x) => {
            let x = fold(x);
            maybe_collapse(Expr::Unary(*op, Box::new(x)), &EMPTY)
        }
        Expr::Binary(op, l, r) => {
            let l = fold(l);
            let r = fold(r);
            maybe_collapse(Expr::Binary(*op, Box::new(l), Box::new(r)), &EMPTY)
        }
        Expr::Cond(c, t, f) => {
            let folded = Expr::Cond(Box::new(fold(c)), Box::new(fold(t)), Box::new(fold(f)));
            maybe_collapse(folded, &EMPTY)
        }
        Expr::Call(name, args) => {
            let folded = Expr::Call(name.clone(), args.iter().map(fold).collect());
            maybe_collapse(folded, &EMPTY)
        }
        Expr::List(xs) => {
            let folded = Expr::List(xs.iter().map(fold).collect());
            maybe_collapse(folded, &EMPTY)
        }
    }
}

/// Collapse `e` to a literal when every immediate child is a literal;
/// evaluation against the empty ad is then context-independent.
fn maybe_collapse(e: Expr, empty: &ClassAd) -> Expr {
    let all_lit = match &e {
        Expr::Unary(_, x) => matches!(**x, Expr::Lit(_)),
        Expr::Binary(_, l, r) => matches!(**l, Expr::Lit(_)) && matches!(**r, Expr::Lit(_)),
        Expr::Cond(c, t, f) => {
            matches!(**c, Expr::Lit(_))
                && matches!(**t, Expr::Lit(_))
                && matches!(**f, Expr::Lit(_))
        }
        Expr::Call(_, args) => args.iter().all(|a| matches!(a, Expr::Lit(_))),
        Expr::List(xs) => xs.iter().all(|x| matches!(x, Expr::Lit(_))),
        _ => false,
    };
    if all_lit {
        Expr::Lit(eval(EvalCtx::solo(empty), &e))
    } else {
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::matchmaker::{rank_of, symmetric_match};
    use crate::classad::parser::{parse_classad, parse_expr};

    const STORAGE: &str = r#"
        hostname = "hugo.mcs.anl.gov";
        availableSpace = 50G;
        MaxRDBandwidth = 75K/Sec;
        requirement = other.reqdSpace < 10G
            && other.reqdRDBandwidth < 75K/Sec;
    "#;

    const REQUEST: &str = r#"
        hostname = "comet.xyz.com";
        reqdSpace = 5G;
        reqdRDBandwidth = 50K/Sec;
        rank = other.availableSpace;
        requirement = other.availableSpace > 5G
            && other.MaxRDBandwidth > 50K/Sec;
    "#;

    #[test]
    fn fold_collapses_literal_subtrees() {
        let e = parse_expr("1 + 2 * 3").unwrap();
        assert_eq!(fold(&e), Expr::Lit(Value::Int(7)));
        let e = parse_expr("{1, 2 + 3}").unwrap();
        assert_eq!(
            fold(&e),
            Expr::Lit(Value::List(vec![Value::Int(1), Value::Int(5)]))
        );
        // 1/0 folds to the ERROR literal — same result, just earlier.
        let e = parse_expr("1 / 0").unwrap();
        assert_eq!(fold(&e), Expr::Lit(Value::Error));
    }

    #[test]
    fn fold_keeps_attr_dependent_subtrees() {
        let e = parse_expr("other.availableSpace > 5 * 1024").unwrap();
        let f = fold(&e);
        // rhs folded, lhs (attr ref) kept.
        match f {
            Expr::Binary(_, l, r) => {
                assert!(matches!(*l, Expr::Attr(..)));
                assert_eq!(*r, Expr::Lit(Value::Int(5120)));
            }
            other => panic!("unexpected fold result {other:?}"),
        }
    }

    #[test]
    fn fold_does_not_partial_fold_booleans() {
        // TRUE && x must stay a conjunction: if x is numeric the result
        // is ERROR, which plain `x` would not produce.
        let e = parse_expr("TRUE && x").unwrap();
        assert!(matches!(fold(&e), Expr::Binary(..)));
    }

    #[test]
    fn compiled_agrees_with_per_pair_on_paper_ads() {
        let request = parse_classad(REQUEST).unwrap();
        let storage = parse_classad(STORAGE).unwrap();
        let cm = CompiledMatch::compile(&request);
        assert_eq!(cm.matches(&storage), symmetric_match(&request, &storage));
        assert_eq!(cm.rank(&storage), rank_of(&request, &storage));
        assert_eq!(cm.rank(&storage), 50.0 * 1024f64.powi(3));
    }

    #[test]
    fn fused_pass_flags_and_ranks() {
        let request = parse_classad(REQUEST).unwrap();
        let mk = |space: &str, bw: &str| {
            parse_classad(&format!("availableSpace = {space}; MaxRDBandwidth = {bw};")).unwrap()
        };
        let cands = vec![
            mk("10G", "60K/Sec"),
            mk("3G", "60K/Sec"),
            mk("80G", "60K/Sec"),
            mk("60G", "40K/Sec"),
            mk("20G", "90K/Sec"),
        ];
        let cm = CompiledMatch::compile(&request);
        let (flags, ranked) = cm.match_and_rank(cands.iter());
        assert_eq!(flags, vec![true, false, true, false, true]);
        assert_eq!(ranked.iter().map(|m| m.index).collect::<Vec<_>>(), vec![2, 4, 0]);
    }

    #[test]
    fn vm_paths_agree_with_tree_path() {
        let request = parse_classad(REQUEST).unwrap();
        let mk = |space: &str, bw: &str| {
            parse_classad(&format!("availableSpace = {space}; MaxRDBandwidth = {bw};")).unwrap()
        };
        let cands = vec![
            mk("10G", "60K/Sec"),
            mk("3G", "60K/Sec"),
            mk("80G", "60K/Sec"),
            mk("60G", "40K/Sec"),
            mk("20G", "90K/Sec"),
        ];
        let cm = CompiledMatch::compile(&request);
        let (flags, ranked) = cm.match_and_rank(cands.iter());
        let (mut f2, mut r2, mut vm) = (Vec::new(), Vec::new(), VmScratch::default());
        cm.match_and_rank_vm_into(cands.iter(), None, &mut f2, &mut r2, &mut vm);
        assert_eq!(flags, f2);
        assert_eq!(ranked, r2);
        let mut table = CandidateTable::default();
        table.rebuild(cm.program(), cands.iter());
        cm.match_and_rank_vm_into(cands.iter(), Some(&table), &mut f2, &mut r2, &mut vm);
        assert_eq!(flags, f2);
        assert_eq!(ranked, r2);
    }

    #[test]
    fn missing_requirements_and_rank_default() {
        let request = parse_classad("reqdSpace = 1G;").unwrap();
        let storage = parse_classad("availableSpace = 50G;").unwrap();
        let cm = CompiledMatch::compile(&request);
        assert!(cm.matches(&storage));
        assert_eq!(cm.rank(&storage), 0.0);
    }

    #[test]
    fn snapshot_is_stable_under_request_mutation() {
        let mut request = parse_classad(REQUEST).unwrap();
        let storage = parse_classad(STORAGE).unwrap();
        let cm = CompiledMatch::compile(&request);
        request.set("requirement", parse_expr("FALSE").unwrap());
        assert!(cm.matches(&storage), "compiled handle must not see later edits");
    }
}
