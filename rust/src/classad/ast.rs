//! ClassAd abstract syntax: expressions and the ad record itself.

use std::collections::HashMap;
use std::fmt;

use super::intern::Sym;
use super::value::Value;

/// Scope qualifier on an attribute reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// Unqualified `attr` — resolved in the local ad first, then (during
    /// matchmaking, per classic semantics) in the other ad.
    Default,
    /// `self.attr` / `my.attr` — local ad only.
    My,
    /// `other.attr` / `target.attr` — the ad on the other side of the
    /// match (UNDEFINED outside a match context).
    Other,
}

/// Binary operators, in the classic ClassAd grammar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Or,        // ||
    And,       // &&
    BitOr,     // |
    BitXor,    // ^
    BitAnd,    // &
    Eq,        // ==
    Ne,        // !=
    Is,        // =?=  (strict)
    Isnt,      // =!=  (strict)
    Lt,        // <
    Le,        // <=
    Gt,        // >
    Ge,        // >=
    Shl,       // <<
    Shr,       // >>  (arithmetic)
    Ushr,      // >>> (logical)
    Add,       // +
    Sub,       // -
    Mul,       // *
    Div,       // /
    Mod,       // %
}

impl BinOp {
    pub fn symbol(self) -> &'static str {
        use BinOp::*;
        match self {
            Or => "||",
            And => "&&",
            BitOr => "|",
            BitXor => "^",
            BitAnd => "&",
            Eq => "==",
            Ne => "!=",
            Is => "=?=",
            Isnt => "=!=",
            Lt => "<",
            Le => "<=",
            Gt => ">",
            Ge => ">=",
            Shl => "<<",
            Shr => ">>",
            Ushr => ">>>",
            Add => "+",
            Sub => "-",
            Mul => "*",
            Div => "/",
            Mod => "%",
        }
    }

    /// Parser precedence (higher binds tighter).
    pub fn precedence(self) -> u8 {
        use BinOp::*;
        match self {
            Or => 1,
            And => 2,
            BitOr => 3,
            BitXor => 4,
            BitAnd => 5,
            Eq | Ne | Is | Isnt => 6,
            Lt | Le | Gt | Ge => 7,
            Shl | Shr | Ushr => 8,
            Add | Sub => 9,
            Mul | Div | Mod => 10,
        }
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Not,    // !
    Neg,    // -
    BitNot, // ~
}

/// An attribute reference inside an expression: the original spelling
/// (for unparsing) plus its interned symbol (for resolution). Equality
/// is case-insensitive (symbol identity), matching attribute semantics.
#[derive(Debug, Clone)]
pub struct AttrName {
    display: Box<str>,
    sym: Sym,
}

impl AttrName {
    pub fn new(name: impl Into<String>) -> AttrName {
        let display: String = name.into();
        let sym = Sym::intern(&display);
        AttrName { display: display.into_boxed_str(), sym }
    }

    pub fn sym(&self) -> Sym {
        self.sym
    }

    pub fn as_str(&self) -> &str {
        &self.display
    }
}

impl From<&str> for AttrName {
    fn from(s: &str) -> Self {
        AttrName::new(s)
    }
}

impl From<String> for AttrName {
    fn from(s: String) -> Self {
        AttrName::new(s)
    }
}

impl PartialEq for AttrName {
    fn eq(&self, other: &Self) -> bool {
        self.sym == other.sym
    }
}

impl fmt::Display for AttrName {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.display)
    }
}

/// A ClassAd expression tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Literal constant.
    Lit(Value),
    /// Attribute reference with optional scope (`other.x`, `my.x`, `x`).
    Attr(Scope, AttrName),
    /// Unary operation.
    Unary(UnOp, Box<Expr>),
    /// Binary operation.
    Binary(BinOp, Box<Expr>, Box<Expr>),
    /// Conditional `c ? t : f`.
    Cond(Box<Expr>, Box<Expr>, Box<Expr>),
    /// Builtin function call.
    Call(String, Vec<Expr>),
    /// List construction `{ e1, e2, ... }`.
    List(Vec<Expr>),
}

impl Expr {
    pub fn lit(v: impl Into<Value>) -> Expr {
        Expr::Lit(v.into())
    }

    pub fn attr(name: impl Into<String>) -> Expr {
        Expr::Attr(Scope::Default, AttrName::new(name))
    }

    pub fn other(name: impl Into<String>) -> Expr {
        Expr::Attr(Scope::Other, AttrName::new(name))
    }

    pub fn my(name: impl Into<String>) -> Expr {
        Expr::Attr(Scope::My, AttrName::new(name))
    }

    pub fn and(self, rhs: Expr) -> Expr {
        Expr::Binary(BinOp::And, Box::new(self), Box::new(rhs))
    }

    pub fn or(self, rhs: Expr) -> Expr {
        Expr::Binary(BinOp::Or, Box::new(self), Box::new(rhs))
    }

    pub fn lt(self, rhs: Expr) -> Expr {
        Expr::Binary(BinOp::Lt, Box::new(self), Box::new(rhs))
    }

    pub fn gt(self, rhs: Expr) -> Expr {
        Expr::Binary(BinOp::Gt, Box::new(self), Box::new(rhs))
    }

    pub fn ge(self, rhs: Expr) -> Expr {
        Expr::Binary(BinOp::Ge, Box::new(self), Box::new(rhs))
    }

    pub fn le(self, rhs: Expr) -> Expr {
        Expr::Binary(BinOp::Le, Box::new(self), Box::new(rhs))
    }

    pub fn eq(self, rhs: Expr) -> Expr {
        Expr::Binary(BinOp::Eq, Box::new(self), Box::new(rhs))
    }
}

/// Unparse with minimal parentheses (child parenthesized when its
/// precedence is lower than the parent's).
fn fmt_expr(e: &Expr, parent_prec: u8, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match e {
        Expr::Lit(v) => write!(f, "{v}"),
        Expr::Attr(Scope::Default, n) => write!(f, "{n}"),
        Expr::Attr(Scope::My, n) => write!(f, "self.{n}"),
        Expr::Attr(Scope::Other, n) => write!(f, "other.{n}"),
        Expr::Unary(op, x) => {
            let sym = match op {
                UnOp::Not => "!",
                UnOp::Neg => "-",
                UnOp::BitNot => "~",
            };
            write!(f, "{sym}")?;
            fmt_expr(x, 11, f)
        }
        Expr::Binary(op, l, r) => {
            let p = op.precedence();
            let need = p < parent_prec;
            if need {
                write!(f, "(")?;
            }
            fmt_expr(l, p, f)?;
            write!(f, " {} ", op.symbol())?;
            fmt_expr(r, p + 1, f)?; // left-assoc: rhs needs strictly higher
            if need {
                write!(f, ")")?;
            }
            Ok(())
        }
        Expr::Cond(c, t, x) => {
            write!(f, "(")?;
            fmt_expr(c, 0, f)?;
            write!(f, " ? ")?;
            fmt_expr(t, 0, f)?;
            write!(f, " : ")?;
            fmt_expr(x, 0, f)?;
            write!(f, ")")
        }
        Expr::Call(name, args) => {
            write!(f, "{name}(")?;
            for (i, a) in args.iter().enumerate() {
                if i > 0 {
                    write!(f, ", ")?;
                }
                fmt_expr(a, 0, f)?;
            }
            write!(f, ")")
        }
        Expr::List(xs) => {
            write!(f, "{{")?;
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    write!(f, ", ")?;
                }
                fmt_expr(x, 0, f)?;
            }
            write!(f, "}}")
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt_expr(self, 0, f)
    }
}

/// A classified advertisement: an ordered attribute → expression record.
///
/// Attribute names are case-insensitive (as in Condor and LDAP); the
/// original spelling is preserved for unparsing. Internally the record
/// is indexed by interned [`Sym`] — lowercasing happens once at insert,
/// and the evaluator's lookups are a single integer-keyed hash probe.
#[derive(Debug, Clone, Default)]
pub struct ClassAd {
    entries: Vec<(String, Expr)>,
    index: HashMap<Sym, usize>,
}

impl ClassAd {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or replace an attribute.
    pub fn set(&mut self, name: impl Into<String>, expr: Expr) {
        let name = name.into();
        let sym = Sym::intern(&name);
        match self.index.get(&sym) {
            Some(&i) => self.entries[i] = (name, expr),
            None => {
                self.index.insert(sym, self.entries.len());
                self.entries.push((name, expr));
            }
        }
    }

    /// Insert a literal value.
    pub fn set_value(&mut self, name: impl Into<String>, v: impl Into<Value>) {
        self.set(name, Expr::Lit(v.into()));
    }

    /// Look up an attribute expression (case-insensitive).
    pub fn get(&self, name: &str) -> Option<&Expr> {
        self.get_sym(Sym::lookup(name)?)
    }

    /// Look up by pre-interned symbol — the evaluator's hot path.
    pub fn get_sym(&self, sym: Sym) -> Option<&Expr> {
        self.index.get(&sym).map(|&i| &self.entries[i].1)
    }

    /// Remove an attribute; returns whether it existed.
    pub fn remove(&mut self, name: &str) -> bool {
        let sym = match Sym::lookup(name) {
            Some(s) => s,
            None => return false,
        };
        match self.index.remove(&sym) {
            None => false,
            Some(i) => {
                self.entries.remove(i);
                for v in self.index.values_mut() {
                    if *v > i {
                        *v -= 1;
                    }
                }
                true
            }
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        Sym::lookup(name).map_or(false, |s| self.index.contains_key(&s))
    }

    pub fn contains_sym(&self, sym: Sym) -> bool {
        self.index.contains_key(&sym)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Iterate attributes in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Expr)> {
        self.entries.iter().map(|(n, e)| (n.as_str(), e))
    }

    /// Evaluate an attribute in this ad alone (no `other` scope).
    pub fn value(&self, name: &str) -> Value {
        super::eval::eval_attr(self, name)
    }

    /// Convenience: evaluated numeric attribute.
    pub fn number(&self, name: &str) -> Option<f64> {
        self.value(name).as_number()
    }

    /// Convenience: evaluated string attribute.
    pub fn string(&self, name: &str) -> Option<String> {
        match self.value(name) {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

impl PartialEq for ClassAd {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len()
            && self.iter().all(|(n, e)| other.get(n) == Some(e))
    }
}

impl fmt::Display for ClassAd {
    /// Unparse in the paper's bare `name = expr;` form.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (name, expr) in self.iter() {
            writeln!(f, "{name} = {expr};")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_case_insensitive() {
        let mut ad = ClassAd::new();
        ad.set_value("AvailableSpace", 5i64);
        assert!(ad.contains("availablespace"));
        assert_eq!(ad.get("AVAILABLESPACE"), Some(&Expr::lit(5i64)));
    }

    #[test]
    fn set_replaces_in_place() {
        let mut ad = ClassAd::new();
        ad.set_value("a", 1i64);
        ad.set_value("b", 2i64);
        ad.set_value("A", 3i64);
        assert_eq!(ad.len(), 2);
        let names: Vec<_> = ad.iter().map(|(n, _)| n.to_string()).collect();
        assert_eq!(names, vec!["A", "b"]);
    }

    #[test]
    fn remove_reindexes() {
        let mut ad = ClassAd::new();
        ad.set_value("a", 1i64);
        ad.set_value("b", 2i64);
        ad.set_value("c", 3i64);
        assert!(ad.remove("b"));
        assert!(!ad.remove("b"));
        assert_eq!(ad.get("c"), Some(&Expr::lit(3i64)));
        assert_eq!(ad.len(), 2);
    }

    #[test]
    fn display_unparse_form() {
        let mut ad = ClassAd::new();
        ad.set_value("hostname", "hugo.mcs.anl.gov");
        ad.set("requirement", Expr::other("reqdSpace").lt(Expr::lit(10i64)));
        let text = ad.to_string();
        assert!(text.contains("hostname = \"hugo.mcs.anl.gov\";"));
        assert!(text.contains("requirement = other.reqdSpace < 10;"));
    }

    #[test]
    fn expr_display_parenthesization() {
        // (a + b) * c must keep its parens; a + b * c must not add any.
        let e = Expr::Binary(
            BinOp::Mul,
            Box::new(Expr::Binary(
                BinOp::Add,
                Box::new(Expr::attr("a")),
                Box::new(Expr::attr("b")),
            )),
            Box::new(Expr::attr("c")),
        );
        assert_eq!(e.to_string(), "(a + b) * c");
        let e2 = Expr::Binary(
            BinOp::Add,
            Box::new(Expr::attr("a")),
            Box::new(Expr::Binary(
                BinOp::Mul,
                Box::new(Expr::attr("b")),
                Box::new(Expr::attr("c")),
            )),
        );
        assert_eq!(e2.to_string(), "a + b * c");
    }
}
