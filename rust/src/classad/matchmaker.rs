//! Condor matchmaking over ClassAds: symmetric `requirements`
//! satisfaction plus `rank`-based ordering — the engine behind the
//! broker's Match phase (paper §5.1.2, steps 2–3).

use super::ast::ClassAd;
use super::eval::eval_in_match;
use super::value::Value;

/// Names accepted for the requirements attribute. The paper's example
/// ads spell it `requirement`; Condor spells it `requirements`. Both
/// are honoured, preferring the ad's own spelling.
const REQUIREMENT_ATTRS: [&str; 2] = ["requirements", "requirement"];

/// Result of matching a request ad against one candidate ad.
#[derive(Debug, Clone, PartialEq)]
pub struct Match {
    /// Index of the candidate in the input slice.
    pub index: usize,
    /// Rank of the match from the *request's* `rank` expression
    /// (0.0 when absent or non-numeric, per Condor).
    pub rank: f64,
}

/// Evaluate one side's requirements against the other.
///
/// Missing requirements mean "always willing" (TRUE), matching the
/// GRIS ads in the paper that publish no `requirements` attribute.
fn requirements_hold(my: &ClassAd, other: &ClassAd) -> bool {
    for attr in REQUIREMENT_ATTRS {
        if my.contains(attr) {
            return matches!(eval_in_match(my, other, attr), Value::Bool(true));
        }
    }
    true
}

/// Symmetric two-way match: both ads' requirements must evaluate to
/// TRUE in the joined (MatchClassAd) context. UNDEFINED and ERROR both
/// fail the match, as in Condor.
pub fn symmetric_match(a: &ClassAd, b: &ClassAd) -> bool {
    requirements_hold(a, b) && requirements_hold(b, a)
}

/// One-way match used where only the requester constrains the pairing.
pub fn match_ads(request: &ClassAd, candidate: &ClassAd) -> bool {
    requirements_hold(request, candidate)
}

/// The request's rank of a candidate: `rank` evaluated with
/// `my = request`, `other = candidate`; non-numeric ranks (including
/// UNDEFINED when the ad has no rank) collapse to 0.0 — Condor's rule.
pub fn rank_of(request: &ClassAd, candidate: &ClassAd) -> f64 {
    eval_in_match(request, candidate, "rank")
        .as_number()
        .unwrap_or(0.0)
}

/// Match `request` against every candidate, returning the survivors
/// ordered best-rank-first (stable for equal ranks, preserving
/// catalog order — the deterministic tiebreak the broker relies on).
///
/// Compiles the request once and runs the fused match+rank pass; for
/// repeated selections against changing candidate sets, hold a
/// [`super::compile::CompiledMatch`] instead of re-calling this.
pub fn rank_candidates(request: &ClassAd, candidates: &[ClassAd]) -> Vec<Match> {
    super::compile::CompiledMatch::compile(request).rank_candidates(candidates)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::parser::parse_classad;

    const STORAGE: &str = r#"
        hostname = "hugo.mcs.anl.gov";
        volume = "/dev/sandbox";
        availableSpace = 50G;
        MaxRDBandwidth = 75K/Sec;
        requirement = other.reqdSpace < 10G
            && other.reqdRDBandwidth < 75K/Sec;
    "#;

    const REQUEST: &str = r#"
        hostname = "comet.xyz.com";
        reqdSpace = 5G;
        reqdRDBandwidth = 50K/Sec;
        rank = other.availableSpace;
        requirement = other.availableSpace > 5G
            && other.MaxRDBandwidth > 50K/Sec;
    "#;

    #[test]
    fn paper_ads_match_both_ways() {
        let s = parse_classad(STORAGE).unwrap();
        let r = parse_classad(REQUEST).unwrap();
        assert!(symmetric_match(&r, &s));
        assert!(symmetric_match(&s, &r));
    }

    #[test]
    fn paper_rank_is_available_space() {
        let s = parse_classad(STORAGE).unwrap();
        let r = parse_classad(REQUEST).unwrap();
        assert_eq!(rank_of(&r, &s), 50.0 * 1024f64.powi(3));
    }

    #[test]
    fn storage_policy_rejects_greedy_request() {
        // Request wanting 20G violates the storage ad's usage policy
        // (other.reqdSpace < 10G) even though its own requirements hold.
        let s = parse_classad(STORAGE).unwrap();
        let r = parse_classad(
            r#"reqdSpace = 20G;
               reqdRDBandwidth = 50K/Sec;
               requirement = other.availableSpace > 5G;"#,
        )
        .unwrap();
        assert!(match_ads(&r, &s));
        assert!(!symmetric_match(&r, &s));
    }

    #[test]
    fn request_rejects_slow_storage() {
        let s = parse_classad(
            r#"availableSpace = 90G;
               MaxRDBandwidth = 10K/Sec;
               requirement = other.reqdSpace < 10G;"#,
        )
        .unwrap();
        let r = parse_classad(REQUEST).unwrap();
        assert!(!symmetric_match(&r, &s));
    }

    #[test]
    fn undefined_requirement_fails_match() {
        // Storage ad references an attribute the request doesn't publish:
        // requirements evaluate UNDEFINED -> no match.
        let s = parse_classad(r#"requirement = other.nonexistent < 5;"#).unwrap();
        let r = parse_classad(r#"reqdSpace = 1G;"#).unwrap();
        assert!(!symmetric_match(&r, &s));
    }

    #[test]
    fn missing_requirements_always_willing() {
        let s = parse_classad("availableSpace = 50G;").unwrap();
        let r = parse_classad("reqdSpace = 1G;").unwrap();
        assert!(symmetric_match(&r, &s));
    }

    #[test]
    fn rank_candidates_orders_best_first() {
        let r = parse_classad(REQUEST).unwrap();
        let mk = |space: &str, bw: &str| {
            parse_classad(&format!(
                "availableSpace = {space}; MaxRDBandwidth = {bw};"
            ))
            .unwrap()
        };
        let candidates = vec![
            mk("10G", "60K/Sec"),  // feasible, rank 10G
            mk("3G", "60K/Sec"),   // infeasible (space)
            mk("80G", "60K/Sec"),  // feasible, rank 80G — winner
            mk("60G", "40K/Sec"),  // infeasible (bandwidth)
            mk("20G", "90K/Sec"),  // feasible, rank 20G
        ];
        let ms = rank_candidates(&r, &candidates);
        assert_eq!(ms.iter().map(|m| m.index).collect::<Vec<_>>(), vec![2, 4, 0]);
        assert!(ms[0].rank > ms[1].rank && ms[1].rank > ms[2].rank);
    }

    #[test]
    fn equal_ranks_tiebreak_by_catalog_order() {
        let r = parse_classad("rank = 1; requirement = TRUE;").unwrap();
        let ads: Vec<_> = (0..4)
            .map(|i| parse_classad(&format!("id = {i};")).unwrap())
            .collect();
        let ms = rank_candidates(&r, &ads);
        assert_eq!(ms.iter().map(|m| m.index).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn rankless_request_ranks_zero() {
        let r = parse_classad("requirement = TRUE;").unwrap();
        let s = parse_classad("availableSpace = 50G;").unwrap();
        assert_eq!(rank_of(&r, &s), 0.0);
    }
}
