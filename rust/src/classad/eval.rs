//! ClassAd evaluator: three-valued logic, `other` scoping, builtins.
//!
//! Evaluation happens either standalone (one ad) or inside a *match
//! context* — the `MatchClassAd` of the Condor papers — where two ads
//! are joined and each can refer to the other through `other.attr`.
//! Per classic semantics, an unqualified attribute is resolved in the
//! local ad first and then in the other ad.

use super::ast::{AttrName, BinOp, ClassAd, Expr, Scope, UnOp};
use super::intern::Sym;
use super::value::Value;

/// Maximum attribute-dereference depth (cycle guard; cycles evaluate to
/// ERROR rather than hanging, mirroring Condor's behaviour). Shared
/// with the bytecode compiler ([`super::program`]), which must apply
/// the same budget when it pre-evaluates request-side subtrees.
pub(crate) const MAX_DEPTH: usize = 64;

/// In-flight attribute frames: `(other-side?, symbol)` pairs. Replaces
/// the old per-eval `HashSet<(bool, String)>` — this lives entirely on
/// the machine stack (no heap allocation per eval) and membership is a
/// linear scan over at most `MAX_DEPTH + 2` integer pairs.
pub(crate) struct CycleStack {
    frames: [(bool, Sym); MAX_DEPTH + 2],
    len: usize,
}

impl CycleStack {
    pub(crate) fn new() -> CycleStack {
        CycleStack { frames: [(false, Sym::DUMMY); MAX_DEPTH + 2], len: 0 }
    }

    /// Push a frame; `false` means the frame is already active (a
    /// cyclic definition) or the stack is full — both evaluate to
    /// ERROR, exactly like the old set-based guard.
    fn push(&mut self, other: bool, sym: Sym) -> bool {
        let frame = (other, sym);
        if self.frames[..self.len].contains(&frame) || self.len >= self.frames.len() {
            return false;
        }
        self.frames[self.len] = frame;
        self.len += 1;
        true
    }

    fn pop(&mut self) {
        self.len -= 1;
    }
}

/// Evaluation context: the local ad and (in a match) the other ad.
#[derive(Clone, Copy)]
pub struct EvalCtx<'a> {
    pub my: &'a ClassAd,
    pub other: Option<&'a ClassAd>,
}

impl<'a> EvalCtx<'a> {
    pub fn solo(my: &'a ClassAd) -> Self {
        EvalCtx { my, other: None }
    }

    pub fn matched(my: &'a ClassAd, other: &'a ClassAd) -> Self {
        EvalCtx { my, other: Some(other) }
    }

    fn flipped(self) -> Option<EvalCtx<'a>> {
        self.other.map(|o| EvalCtx { my: o, other: Some(self.my) })
    }
}

/// Evaluate `expr` in `ctx`.
pub fn eval(ctx: EvalCtx<'_>, expr: &Expr) -> Value {
    let mut stack = CycleStack::new();
    eval_inner(ctx, expr, &mut stack, 0)
}

/// Evaluate attribute `name` of `ad` with no match partner.
pub fn eval_attr(ad: &ClassAd, name: &str) -> Value {
    match ad.get(name) {
        Some(e) => eval(EvalCtx::solo(ad), e),
        None => Value::Undefined,
    }
}

/// Evaluate attribute `name` of `my` inside a match with `other`.
pub fn eval_in_match(my: &ClassAd, other: &ClassAd, name: &str) -> Value {
    match my.get(name) {
        Some(e) => eval(EvalCtx::matched(my, other), e),
        None => Value::Undefined,
    }
}

fn eval_inner(
    ctx: EvalCtx<'_>,
    expr: &Expr,
    stack: &mut CycleStack,
    depth: usize,
) -> Value {
    if depth > MAX_DEPTH {
        return Value::Error;
    }
    match expr {
        Expr::Lit(v) => v.clone(),
        Expr::Attr(scope, name) => resolve_attr(ctx, *scope, name, stack, depth),
        Expr::Unary(op, x) => {
            let v = eval_inner(ctx, x, stack, depth + 1);
            apply_unary(*op, &v)
        }
        Expr::Binary(op, l, r) => eval_binary(ctx, *op, l, r, stack, depth),
        Expr::Cond(c, t, f) => match eval_inner(ctx, c, stack, depth + 1) {
            Value::Bool(true) => eval_inner(ctx, t, stack, depth + 1),
            Value::Bool(false) => eval_inner(ctx, f, stack, depth + 1),
            Value::Undefined => Value::Undefined,
            _ => Value::Error,
        },
        Expr::Call(name, args) => {
            let vals: Vec<Value> = args
                .iter()
                .map(|a| eval_inner(ctx, a, stack, depth + 1))
                .collect();
            builtins::call_vals(name, &vals)
        }
        Expr::List(xs) => Value::List(
            xs.iter()
                .map(|x| eval_inner(ctx, x, stack, depth + 1))
                .collect(),
        ),
    }
}

fn resolve_attr(
    ctx: EvalCtx<'_>,
    scope: Scope,
    name: &AttrName,
    stack: &mut CycleStack,
    depth: usize,
) -> Value {
    let sym = name.sym();
    match scope {
        Scope::My => resolve_side(ctx, false, sym, stack, depth).unwrap_or(Value::Undefined),
        Scope::Other => resolve_side(ctx, true, sym, stack, depth).unwrap_or(Value::Undefined),
        Scope::Default => resolve_side(ctx, false, sym, stack, depth)
            .or_else(|| resolve_side(ctx, true, sym, stack, depth))
            .unwrap_or(Value::Undefined),
    }
}

/// Resolve `sym` in the local (`other == false`) or flipped ad.
/// `None` when the attribute is absent (or there is no other ad);
/// cyclic definitions evaluate to `Some(Error)`.
fn resolve_side(
    ctx: EvalCtx<'_>,
    other: bool,
    sym: Sym,
    stack: &mut CycleStack,
    depth: usize,
) -> Option<Value> {
    let target = if other { ctx.flipped()? } else { ctx };
    let e = target.my.get_sym(sym)?;
    // Literal attributes (the overwhelmingly common case in converted
    // GRIS ads) cannot participate in a cycle: skip the guard frame.
    if let Expr::Lit(v) = e {
        return Some(v.clone());
    }
    if !stack.push(other, sym) {
        return Some(Value::Error); // cyclic definition
    }
    let v = eval_inner(target, e, stack, depth + 1);
    stack.pop();
    Some(v)
}

/// The VM's one-op escape hatch ([`super::program`]): resolve `sym`
/// exactly as [`resolve_attr`] would at an `Attr` node sitting at
/// `depth` in a *top-level* expression. The guard stack is empty there
/// by construction — frames only accumulate inside attribute
/// definitions (via [`resolve_side`]), never across the structural
/// walk of the expression being evaluated.
pub(crate) fn resolve_at_depth(ctx: EvalCtx<'_>, other: bool, sym: Sym, depth: usize) -> Value {
    let mut stack = CycleStack::new();
    resolve_side(ctx, other, sym, &mut stack, depth).unwrap_or(Value::Undefined)
}

/// Unary-operator semantics on an already-evaluated operand. One body
/// for the tree-walker and the bytecode VM.
pub(crate) fn apply_unary(op: UnOp, v: &Value) -> Value {
    if v.is_exceptional() {
        return v.clone();
    }
    match op {
        UnOp::Not => match v {
            Value::Bool(b) => Value::Bool(!b),
            _ => Value::Error,
        },
        UnOp::Neg => match v {
            Value::Int(i) => Value::Int(-i),
            Value::Real(r) => Value::Real(-r),
            Value::Quantity { base, rate } => Value::Quantity { base: -base, rate: *rate },
            _ => Value::Error,
        },
        UnOp::BitNot => match v {
            Value::Int(i) => Value::Int(!i),
            _ => Value::Error,
        },
    }
}

/// The lazy operators' left-operand decision: `Some(v)` when the right
/// side must NOT be evaluated (`FALSE &&`, `TRUE ||`, or a left operand
/// that is ERROR / non-boolean), `None` when it must (left is the
/// neutral boolean or UNDEFINED).
pub(crate) fn lazy_decided(or: bool, lv: &Value) -> Option<Value> {
    match lv {
        Value::Bool(b) if *b == or => Some(Value::Bool(or)),
        Value::Bool(_) | Value::Undefined => None,
        _ => Some(Value::Error),
    }
}

/// The lazy operators' combine table, applied only after
/// [`lazy_decided`] returned `None` (so `lv` is the neutral boolean or
/// UNDEFINED): UNDEFINED is absorbed when the right side decides the
/// result (`UNDEFINED && FALSE == FALSE`; `UNDEFINED || TRUE == TRUE`).
pub(crate) fn lazy_combine(or: bool, lv: &Value, rv: &Value) -> Value {
    match (lv, rv) {
        (_, Value::Error) => Value::Error,
        (Value::Bool(_), Value::Bool(b)) => Value::Bool(*b),
        (Value::Undefined, Value::Bool(b)) => {
            if *b == or {
                Value::Bool(*b)
            } else {
                Value::Undefined
            }
        }
        (_, Value::Undefined) => Value::Undefined,
        _ => Value::Error,
    }
}

/// Strict (non-lazy) binary-operator semantics on already-evaluated
/// operands — everything except `&&`/`||`, whose left operand gates
/// right-operand evaluation and so cannot be expressed value-on-value.
/// One body for the tree-walker and the bytecode VM.
pub(crate) fn apply_binary(op: BinOp, lv: &Value, rv: &Value) -> Value {
    use BinOp::*;
    // Strict comparisons never propagate UNDEFINED/ERROR.
    if op == Is {
        return Value::Bool(lv.strict_eq(rv));
    }
    if op == Isnt {
        return Value::Bool(!lv.strict_eq(rv));
    }
    if lv.is_exceptional() || rv.is_exceptional() {
        return if lv.is_error() || rv.is_error() {
            Value::Error
        } else {
            Value::Undefined
        };
    }
    match op {
        Eq | Ne => match lv.loose_eq(rv) {
            Some(b) => Value::Bool(if op == Eq { b } else { !b }),
            None => Value::Error,
        },
        Lt | Le | Gt | Ge => match lv.loose_cmp(rv) {
            Some(ord) => {
                let b = match op {
                    Lt => ord.is_lt(),
                    Le => ord.is_le(),
                    Gt => ord.is_gt(),
                    Ge => ord.is_ge(),
                    _ => unreachable!(),
                };
                Value::Bool(b)
            }
            None => Value::Error,
        },
        Add | Sub | Mul | Div | Mod => arith(op, lv, rv),
        BitOr | BitXor | BitAnd | Shl | Shr | Ushr => bits(op, lv, rv),
        And | Or | Is | Isnt => unreachable!(),
    }
}

fn eval_binary(
    ctx: EvalCtx<'_>,
    op: BinOp,
    l: &Expr,
    r: &Expr,
    stack: &mut CycleStack,
    depth: usize,
) -> Value {
    use BinOp::*;
    // Lazy boolean operators with UNDEFINED-absorption.
    if op == And || op == Or {
        let or = op == Or;
        let lv = eval_inner(ctx, l, stack, depth + 1);
        if let Some(v) = lazy_decided(or, &lv) {
            return v;
        }
        let rv = eval_inner(ctx, r, stack, depth + 1);
        return lazy_combine(or, &lv, &rv);
    }
    let lv = eval_inner(ctx, l, stack, depth + 1);
    let rv = eval_inner(ctx, r, stack, depth + 1);
    apply_binary(op, &lv, &rv)
}

fn arith(op: BinOp, lv: &Value, rv: &Value) -> Value {
    use BinOp::*;
    // String + string concatenates (convenience used by converted ads).
    if op == Add {
        if let (Value::Str(a), Value::Str(b)) = (lv, rv) {
            return Value::Str(format!("{a}{b}"));
        }
    }
    let both_int = matches!((lv, rv), (Value::Int(_), Value::Int(_)));
    let (a, b) = match (lv.as_number(), rv.as_number()) {
        (Some(a), Some(b)) => (a, b),
        _ => return Value::Error,
    };
    if both_int {
        let (a, b) = (a as i64, b as i64);
        return match op {
            Add => Value::Int(a.wrapping_add(b)),
            Sub => Value::Int(a.wrapping_sub(b)),
            Mul => Value::Int(a.wrapping_mul(b)),
            Div => {
                if b == 0 {
                    Value::Error
                } else {
                    Value::Int(a.wrapping_div(b))
                }
            }
            Mod => {
                if b == 0 {
                    Value::Error
                } else {
                    Value::Int(a.wrapping_rem(b))
                }
            }
            _ => unreachable!(),
        };
    }
    match op {
        Add => Value::Real(a + b),
        Sub => Value::Real(a - b),
        Mul => Value::Real(a * b),
        Div => {
            if b == 0.0 {
                Value::Error
            } else {
                Value::Real(a / b)
            }
        }
        Mod => {
            if b == 0.0 {
                Value::Error
            } else {
                Value::Real(a % b)
            }
        }
        _ => unreachable!(),
    }
}

fn bits(op: BinOp, lv: &Value, rv: &Value) -> Value {
    use BinOp::*;
    let (a, b) = match (lv, rv) {
        (Value::Int(a), Value::Int(b)) => (*a, *b),
        _ => return Value::Error,
    };
    match op {
        BitOr => Value::Int(a | b),
        BitXor => Value::Int(a ^ b),
        BitAnd => Value::Int(a & b),
        Shl => Value::Int(a.wrapping_shl(b as u32)),
        Shr => Value::Int(a.wrapping_shr(b as u32)),
        Ushr => Value::Int(((a as u64).wrapping_shr(b as u32)) as i64),
        _ => unreachable!(),
    }
}

/// Builtin function library.
pub mod builtins {
    use super::*;
    use crate::util::rex::Rex;
    use once_cell::sync::Lazy;
    use std::sync::Arc;

    static REGEX_CACHE: Lazy<std::sync::Mutex<std::collections::HashMap<String, Arc<Rex>>>> =
        Lazy::new(|| std::sync::Mutex::new(std::collections::HashMap::new()));

    /// Dispatch a builtin by (lowercased) name.
    pub fn call(name: &str, vals: &[Value], _args: &[Expr], _ctx: EvalCtx<'_>) -> Value {
        call_vals(name, vals)
    }

    /// Value-only dispatch — the body shared by the tree-walker and the
    /// bytecode VM ([`super::super::program`]); every builtin is strict
    /// in its (already evaluated) arguments.
    pub(crate) fn call_vals(name: &str, vals: &[Value]) -> Value {
        // Any ERROR argument poisons the call; UNDEFINED poisons except
        // for the explicit type-test builtins.
        let type_test = matches!(
            name,
            "isundefined" | "iserror" | "isstring" | "isinteger" | "isreal" | "isboolean" | "islist"
        );
        if !type_test {
            if vals.iter().any(|v| v.is_error()) {
                return Value::Error;
            }
            if vals.iter().any(|v| v.is_undefined()) {
                return Value::Undefined;
            }
        }
        match (name, vals) {
            ("isundefined", [v]) => Value::Bool(v.is_undefined()),
            ("iserror", [v]) => Value::Bool(v.is_error()),
            ("isstring", [v]) => Value::Bool(matches!(v, Value::Str(_))),
            ("isinteger", [v]) => Value::Bool(matches!(v, Value::Int(_))),
            ("isreal", [v]) => Value::Bool(matches!(v, Value::Real(_) | Value::Quantity { .. })),
            ("isboolean", [v]) => Value::Bool(matches!(v, Value::Bool(_))),
            ("islist", [v]) => Value::Bool(matches!(v, Value::List(_))),
            ("typeof", [v]) => Value::Str(v.type_name().into()),

            ("int", [v]) => match v.as_number() {
                Some(n) => Value::Int(n as i64),
                None => match v {
                    Value::Str(s) => s
                        .trim()
                        .parse::<i64>()
                        .map(Value::Int)
                        .unwrap_or(Value::Error),
                    Value::Bool(b) => Value::Int(*b as i64),
                    _ => Value::Error,
                },
            },
            ("real", [v]) => match v.as_number() {
                Some(n) => Value::Real(n),
                None => match v {
                    Value::Str(s) => s
                        .trim()
                        .parse::<f64>()
                        .map(Value::Real)
                        .unwrap_or(Value::Error),
                    Value::Bool(b) => Value::Real(*b as i64 as f64),
                    _ => Value::Error,
                },
            },
            ("string", [v]) => match v {
                Value::Str(s) => Value::Str(s.clone()),
                other => Value::Str(other.to_string()),
            },
            ("floor", [v]) => num1(v, f64::floor),
            ("ceiling", [v]) => num1(v, f64::ceil),
            ("round", [v]) => num1(v, f64::round),
            ("abs", [v]) => match v {
                Value::Int(i) => Value::Int(i.abs()),
                other => match other.as_number() {
                    Some(n) => Value::Real(n.abs()),
                    None => Value::Error,
                },
            },
            ("min", vs) if !vs.is_empty() => fold_num(vs, f64::min),
            ("max", vs) if !vs.is_empty() => fold_num(vs, f64::max),

            ("strcat", vs) => {
                let mut out = String::new();
                for v in vs {
                    match v {
                        Value::Str(s) => out.push_str(s),
                        other => out.push_str(&other.to_string()),
                    }
                }
                Value::Str(out)
            }
            ("strlen" | "size", [Value::Str(s)]) => Value::Int(s.len() as i64),
            ("size", [Value::List(xs)]) => Value::Int(xs.len() as i64),
            ("toupper", [Value::Str(s)]) => Value::Str(s.to_uppercase()),
            ("tolower", [Value::Str(s)]) => Value::Str(s.to_lowercase()),
            ("substr", [Value::Str(s), Value::Int(off)]) => substr(s, *off, i64::MAX),
            ("substr", [Value::Str(s), Value::Int(off), Value::Int(len)]) => {
                substr(s, *off, *len)
            }
            ("member", [x, Value::List(xs)]) => {
                Value::Bool(xs.iter().any(|v| v.loose_eq(x) == Some(true)))
            }
            ("regexp", [Value::Str(pat), Value::Str(s)]) => {
                let re = {
                    let mut cache = REGEX_CACHE.lock().unwrap();
                    match cache.get(pat) {
                        Some(re) => re.clone(),
                        None => match Rex::new(pat) {
                            Ok(re) => {
                                let re = Arc::new(re);
                                cache.insert(pat.clone(), re.clone());
                                re
                            }
                            Err(_) => return Value::Error,
                        },
                    }
                };
                Value::Bool(re.is_match(s))
            }
            ("ifthenelse", [c, t, f]) => match c {
                Value::Bool(true) => t.clone(),
                Value::Bool(false) => f.clone(),
                _ => Value::Error,
            },
            _ => Value::Error,
        }
    }

    fn num1(v: &Value, f: impl Fn(f64) -> f64) -> Value {
        match v {
            Value::Int(i) => Value::Int(*i),
            other => match other.as_number() {
                Some(n) => Value::Int(f(n) as i64),
                None => Value::Error,
            },
        }
    }

    fn fold_num(vs: &[Value], f: impl Fn(f64, f64) -> f64) -> Value {
        let mut acc: Option<f64> = None;
        let all_int = vs.iter().all(|v| matches!(v, Value::Int(_)));
        for v in vs {
            match v.as_number() {
                Some(n) => acc = Some(acc.map_or(n, |a| f(a, n))),
                None => return Value::Error,
            }
        }
        let n = acc.unwrap();
        if all_int {
            Value::Int(n as i64)
        } else {
            Value::Real(n)
        }
    }

    fn substr(s: &str, off: i64, len: i64) -> Value {
        let chars: Vec<char> = s.chars().collect();
        let n = chars.len() as i64;
        let start = if off < 0 { (n + off).max(0) } else { off.min(n) };
        let avail = n - start;
        let take = if len < 0 { (avail + len).max(0) } else { len.min(avail) };
        Value::Str(chars[start as usize..(start + take) as usize].iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::classad::parser::{parse_classad, parse_expr};

    fn ev(src: &str) -> Value {
        let ad = ClassAd::new();
        eval(EvalCtx::solo(&ad), &parse_expr(src).unwrap())
    }

    #[test]
    fn arithmetic_int_and_real() {
        assert_eq!(ev("1 + 2 * 3"), Value::Int(7));
        assert_eq!(ev("7 / 2"), Value::Int(3));
        assert_eq!(ev("7.0 / 2"), Value::Real(3.5));
        assert_eq!(ev("7 % 3"), Value::Int(1));
        assert_eq!(ev("1 / 0"), Value::Error);
        assert_eq!(ev("-3"), Value::Int(-3));
    }

    #[test]
    fn quantities_behave_numerically() {
        assert_eq!(ev("5G < 10G"), Value::Bool(true));
        assert_eq!(ev("1K + 1"), Value::Real(1025.0));
        assert_eq!(ev("75K/Sec > 50K/Sec"), Value::Bool(true));
    }

    #[test]
    fn three_valued_logic_tables() {
        assert_eq!(ev("FALSE && UNDEFINED"), Value::Bool(false));
        assert_eq!(ev("UNDEFINED && FALSE"), Value::Bool(false));
        assert_eq!(ev("TRUE && UNDEFINED"), Value::Undefined);
        assert_eq!(ev("UNDEFINED || TRUE"), Value::Bool(true));
        assert_eq!(ev("UNDEFINED || FALSE"), Value::Undefined);
        assert_eq!(ev("missing > 5"), Value::Undefined);
        assert_eq!(ev("TRUE && ERROR"), Value::Error);
        assert_eq!(ev("1 && TRUE"), Value::Error);
    }

    #[test]
    fn strict_comparison_pierces_undefined() {
        assert_eq!(ev("UNDEFINED =?= UNDEFINED"), Value::Bool(true));
        assert_eq!(ev("missing =?= UNDEFINED"), Value::Bool(true));
        assert_eq!(ev("1 =?= 1.0"), Value::Bool(false));
        assert_eq!(ev("\"A\" =?= \"a\""), Value::Bool(false));
        assert_eq!(ev("\"A\" == \"a\""), Value::Bool(true));
        assert_eq!(ev("UNDEFINED =!= UNDEFINED"), Value::Bool(false));
    }

    #[test]
    fn attr_chains_and_cycles() {
        let ad = parse_classad("a = b + 1; b = 2;").unwrap();
        assert_eq!(ad.value("a"), Value::Int(3));
        let cyc = parse_classad("a = b; b = a;").unwrap();
        assert_eq!(cyc.value("a"), Value::Error);
        let selfcyc = parse_classad("a = a + 1;").unwrap();
        assert_eq!(selfcyc.value("a"), Value::Error);
    }

    #[test]
    fn other_scope_resolution() {
        let a = parse_classad("x = 1; req = other.y == 2;").unwrap();
        let b = parse_classad("y = 2;").unwrap();
        assert_eq!(eval_in_match(&a, &b, "req"), Value::Bool(true));
        // other.* outside a match is UNDEFINED
        assert_eq!(a.value("req"), Value::Undefined);
    }

    #[test]
    fn default_scope_falls_through_to_other() {
        // Classic semantics: unqualified name looks at my ad, then other.
        let a = parse_classad("req = y == 2;").unwrap();
        let b = parse_classad("y = 2;").unwrap();
        assert_eq!(eval_in_match(&a, &b, "req"), Value::Bool(true));
    }

    #[test]
    fn conditional() {
        assert_eq!(ev("1 < 2 ? \"yes\" : \"no\""), Value::from("yes"));
        assert_eq!(ev("UNDEFINED ? 1 : 2"), Value::Undefined);
        assert_eq!(ev("3 ? 1 : 2"), Value::Error);
    }

    #[test]
    fn builtin_strings() {
        assert_eq!(ev("strcat(\"a\", \"b\", 3)"), Value::from("ab3"));
        assert_eq!(ev("toUpper(\"abc\")"), Value::from("ABC"));
        assert_eq!(ev("strlen(\"abcd\")"), Value::Int(4));
        assert_eq!(ev("substr(\"abcdef\", 2, 3)"), Value::from("cde"));
        assert_eq!(ev("substr(\"abcdef\", -2)"), Value::from("ef"));
        assert_eq!(ev("regexp(\"^hu.*gov$\", \"hugo.mcs.anl.gov\")"), Value::Bool(true));
    }

    #[test]
    fn builtin_numeric_and_lists() {
        assert_eq!(ev("floor(2.9)"), Value::Int(2));
        assert_eq!(ev("ceiling(2.1)"), Value::Int(3));
        assert_eq!(ev("round(2.5)"), Value::Int(3));
        assert_eq!(ev("min(3, 1.5, 2)"), Value::Real(1.5));
        assert_eq!(ev("max(3, 5)"), Value::Int(5));
        assert_eq!(ev("member(\"xfs\", {\"ext3\", \"xfs\"})"), Value::Bool(true));
        assert_eq!(ev("member(4, {1, 2, 3})"), Value::Bool(false));
        assert_eq!(ev("size({1, 2, 3})"), Value::Int(3));
    }

    #[test]
    fn builtin_type_tests_see_undefined() {
        assert_eq!(ev("isUndefined(missing)"), Value::Bool(true));
        assert_eq!(ev("isError(1/0)"), Value::Bool(true));
        assert_eq!(ev("isString(\"x\")"), Value::Bool(true));
        assert_eq!(ev("isReal(5G)"), Value::Bool(true));
    }

    #[test]
    fn unknown_builtin_is_error() {
        assert_eq!(ev("frobnicate(1)"), Value::Error);
    }

    #[test]
    fn bitwise_ops() {
        assert_eq!(ev("5 & 3"), Value::Int(1));
        assert_eq!(ev("5 | 3"), Value::Int(7));
        assert_eq!(ev("5 ^ 3"), Value::Int(6));
        assert_eq!(ev("1 << 4"), Value::Int(16));
        assert_eq!(ev("-8 >> 1"), Value::Int(-4));
        assert_eq!(ev("~0"), Value::Int(-1));
    }
}

#[cfg(test)]
mod depth_tests {
    use super::*;
    use crate::classad::parser::parse_classad;

    #[test]
    fn deep_attribute_chains_hit_the_guard_not_the_stack() {
        // a0 -> a1 -> ... -> a100: deeper than MAX_DEPTH, evaluates to
        // ERROR instead of overflowing.
        let mut src = String::new();
        for i in 0..100 {
            src.push_str(&format!("a{i} = a{};\n", i + 1));
        }
        src.push_str("a100 = 1;\n");
        let ad = parse_classad(&src).unwrap();
        assert_eq!(ad.value("a0"), Value::Error);
        // A chain inside the budget still resolves.
        let mut ok = String::new();
        for i in 0..30 {
            ok.push_str(&format!("b{i} = b{};\n", i + 1));
        }
        ok.push_str("b30 = 7;\n");
        let ad2 = parse_classad(&ok).unwrap();
        assert_eq!(ad2.value("b0"), Value::Int(7));
    }

    #[test]
    fn mutual_recursion_through_other_scope_terminates() {
        let a = parse_classad("x = other.y; requirement = other.y > 0;").unwrap();
        let b = parse_classad("y = other.x;").unwrap();
        // x -> other.y -> other.x (cycle across ads) must be ERROR.
        assert_eq!(eval_in_match(&a, &b, "x"), Value::Error);
    }
}
