//! R3 — matchmaking cost (implied by paper §4): symmetric match + rank
//! throughput as the candidate set grows.
//!
//! The paper's broker matches one request ad against every replica
//! site's ad; this bench measures that Match-phase core from a single
//! pair up to 4096 candidates, the **compiled/batch path**
//! ([`CompiledMatch`], compile-once / match-many) against the per-pair
//! path at 1,000 candidates, plus expression-evaluation and parser
//! microbenches.
//!
//! With `BENCH_JSON=<path>` set, the headline numbers (ns/op, ops/sec,
//! and the compiled-vs-per-pair speedup at 1,000 candidates) are
//! written as JSON — `scripts/bench.sh` uses this to record
//! `BENCH_matchmaking.json`.

use std::collections::BTreeMap;

use globus_replica::classad::{
    parse_classad, parse_expr, rank_candidates, rank_of, symmetric_match, AdBuilder,
    CandidateTable, ClassAd, CompiledMatch, Match, VmScratch,
};
use globus_replica::util::bench::{Bench, Stats};
use globus_replica::util::json::Json;
use globus_replica::util::prng::Rng;

fn storage_ads(n: usize, seed: u64) -> Vec<ClassAd> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            AdBuilder::new()
                .str("hostname", format!("site{i}.grid"))
                .bytes("availableSpace", rng.range(1.0, 100.0) * 1024f64.powi(3))
                .rate("MaxRDBandwidth", rng.range(10.0, 100.0) * 1024.0)
                .rate("AvgRDBandwidth", rng.range(10.0, 100.0) * 1024.0)
                .real("load", rng.range(0.0, 1.0))
                .expr(
                    "requirements",
                    "other.reqdSpace < 10G && other.reqdRDBandwidth < 100K/Sec",
                )
                .build()
        })
        .collect()
}

fn request() -> ClassAd {
    parse_classad(
        r#"hostname = "comet.xyz.com";
           reqdSpace = 5G;
           reqdRDBandwidth = 50K/Sec;
           rank = other.availableSpace;
           requirement = other.availableSpace > 5G
               && other.MaxRDBandwidth > 50K/Sec;"#,
    )
    .unwrap()
}

/// The per-pair path: requirements matched per candidate through the
/// string-keyed public API, rank per survivor, sort — what the broker
/// ran before the compiled engine existed.
fn per_pair_rank(req: &ClassAd, ads: &[ClassAd]) -> Vec<Match> {
    let mut out: Vec<Match> = ads
        .iter()
        .enumerate()
        .filter(|(_, c)| symmetric_match(req, c))
        .map(|(index, c)| Match { index, rank: rank_of(req, c) })
        .collect();
    out.sort_by(|a, b| {
        b.rank
            .partial_cmp(&a.rank)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.index.cmp(&b.index))
    });
    out
}

fn main() {
    let req = request();
    let mut b = Bench::new("matchmaking (paper §4; R3)");

    let pair = storage_ads(1, 7);
    b.case("symmetric_match/1 pair", || symmetric_match(&req, &pair[0]));

    for n in [4usize, 16, 64, 256, 1024, 4096] {
        let ads = storage_ads(n, 42 + n as u64);
        b.case_items(&format!("match+rank/{n} candidates"), n as f64, || {
            rank_candidates(&req, &ads).len()
        });
    }

    // Headline comparison (ISSUE 2 acceptance): per-pair vs the
    // compiled/batch path over the same 1,000-candidate set. The
    // compiled case includes the compile step — that is the honest
    // batch cost (compile once, then stream the candidate set).
    let n1000 = 1000usize;
    let ads1000 = storage_ads(n1000, 1000);
    b.case_items(&format!("per-pair/{n1000} candidates"), n1000 as f64, || {
        per_pair_rank(&req, &ads1000).len()
    });
    b.case_items(&format!("compiled/{n1000} candidates"), n1000 as f64, || {
        CompiledMatch::compile(&req).rank_candidates(&ads1000).len()
    });
    // Amortized variant: one compile reused across the whole run (the
    // broker's `PreparedRequest` shape).
    let compiled = CompiledMatch::compile(&req);
    b.case_items(
        &format!("compiled-reused/{n1000} candidates"),
        n1000 as f64,
        || compiled.rank_candidates(&ads1000).len(),
    );
    // PR 9 headline: the bytecode VM against the reused tree-walk above
    // — same compiled handle, same candidate set, scratch reused across
    // iterations (the broker's `SelectScratch` shape).
    let mut vm = VmScratch::default();
    let (mut vflags, mut vms) = (Vec::new(), Vec::new());
    b.case_items(&format!("program/{n1000} candidates"), n1000 as f64, || {
        compiled.match_and_rank_vm_into(ads1000.iter(), None, &mut vflags, &mut vms, &mut vm);
        vms.len()
    });
    // Batch-throughput shape: rebuild the dense table per batch (that is
    // conversion work, counted here for honesty) and run the program
    // down the columns.
    let mut table = CandidateTable::default();
    b.case_items(&format!("program-table/{n1000} candidates"), n1000 as f64, || {
        table.rebuild(compiled.program(), ads1000.iter());
        compiled.match_and_rank_vm_into(
            ads1000.iter(),
            Some(&table),
            &mut vflags,
            &mut vms,
            &mut vm,
        );
        vms.len()
    });

    // Expression microbenches: the requirement expression that every
    // match evaluates twice.
    let e = parse_expr("other.availableSpace > 5G && other.MaxRDBandwidth > 50K/Sec").unwrap();
    let storage = &storage_ads(1, 9)[0];
    b.case("eval requirement expr", || {
        globus_replica::classad::eval(
            globus_replica::classad::EvalCtx::matched(&req, storage),
            &e,
        )
    });

    b.case("parse request ad", || {
        parse_classad(
            r#"reqdSpace = 5G; reqdRDBandwidth = 50K/Sec;
               rank = other.availableSpace;
               requirement = other.availableSpace > 5G && other.MaxRDBandwidth > 50K/Sec;"#,
        )
        .unwrap()
    });

    let stats = b.finish();
    // Sanity for EXPERIMENTS.md: match+rank over 1024 ads should beat
    // 10^5 ads/s single-thread (DESIGN.md §Perf target).
    if let Some(s) = stats.iter().find(|s| s.name.contains("1024")) {
        println!(
            "\nthroughput @1024 candidates: {:.0} ads/s (target ≥ 100000)",
            s.throughput()
        );
    }
    let find = |needle: &str| stats.iter().find(|s| s.name.starts_with(needle));
    let speedup = match (find("per-pair/1000"), find("compiled/1000")) {
        (Some(pp), Some(c)) if c.mean_ns > 0.0 => {
            let x = pp.mean_ns / c.mean_ns;
            println!(
                "compiled-vs-per-pair @1000 candidates: {x:.2}x (acceptance target ≥ 5x)"
            );
            Some(x)
        }
        _ => None,
    };
    // PR 9 headline: bytecode program vs the reused tree-walk, both
    // amortizing their compile across the run.
    let speedup_vm = match (find("compiled-reused/1000"), find("program/1000")) {
        (Some(tree), Some(vm)) if vm.mean_ns > 0.0 => {
            let x = tree.mean_ns / vm.mean_ns;
            println!("program-vs-tree @1000 candidates: {x:.2}x");
            Some(x)
        }
        _ => None,
    };

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("matchmaking".to_string()));
        root.insert(
            "cases".to_string(),
            Json::Arr(stats.iter().map(Stats::to_json).collect()),
        );
        if let Some(x) = speedup {
            root.insert(
                "speedup_compiled_vs_perpair_1000".to_string(),
                Json::Num(x),
            );
        }
        if let Some(x) = speedup_vm {
            root.insert("speedup_program_vs_tree_1000".to_string(), Json::Num(x));
        }
        let body = Json::Obj(root).to_string();
        match std::fs::write(&path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
