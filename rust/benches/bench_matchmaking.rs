//! R3 — matchmaking cost (implied by paper §4): symmetric match + rank
//! throughput as the candidate set grows.
//!
//! The paper's broker matches one request ad against every replica
//! site's storage ad; this bench measures that Match-phase core from a
//! single pair up to 4096 candidates, plus expression-evaluation and
//! parser microbenches.

use globus_replica::classad::{
    parse_classad, parse_expr, rank_candidates, symmetric_match, AdBuilder, ClassAd,
};
use globus_replica::util::bench::Bench;
use globus_replica::util::prng::Rng;

fn storage_ads(n: usize, seed: u64) -> Vec<ClassAd> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            AdBuilder::new()
                .str("hostname", format!("site{i}.grid"))
                .bytes("availableSpace", rng.range(1.0, 100.0) * 1024f64.powi(3))
                .rate("MaxRDBandwidth", rng.range(10.0, 100.0) * 1024.0)
                .rate("AvgRDBandwidth", rng.range(10.0, 100.0) * 1024.0)
                .real("load", rng.range(0.0, 1.0))
                .expr(
                    "requirements",
                    "other.reqdSpace < 10G && other.reqdRDBandwidth < 100K/Sec",
                )
                .build()
        })
        .collect()
}

fn request() -> ClassAd {
    parse_classad(
        r#"hostname = "comet.xyz.com";
           reqdSpace = 5G;
           reqdRDBandwidth = 50K/Sec;
           rank = other.availableSpace;
           requirement = other.availableSpace > 5G
               && other.MaxRDBandwidth > 50K/Sec;"#,
    )
    .unwrap()
}

fn main() {
    let req = request();
    let mut b = Bench::new("matchmaking (paper §4; R3)");

    let pair = storage_ads(1, 7);
    b.case("symmetric_match/1 pair", || symmetric_match(&req, &pair[0]));

    for n in [4usize, 16, 64, 256, 1024, 4096] {
        let ads = storage_ads(n, 42 + n as u64);
        b.case_items(&format!("match+rank/{n} candidates"), n as f64, || {
            rank_candidates(&req, &ads).len()
        });
    }

    // Expression microbenches: the requirement expression that every
    // match evaluates twice.
    let e = parse_expr("other.availableSpace > 5G && other.MaxRDBandwidth > 50K/Sec").unwrap();
    let storage = &storage_ads(1, 9)[0];
    b.case("eval requirement expr", || {
        globus_replica::classad::eval(
            globus_replica::classad::EvalCtx::matched(&req, storage),
            &e,
        )
    });

    b.case("parse request ad", || {
        parse_classad(
            r#"reqdSpace = 5G; reqdRDBandwidth = 50K/Sec;
               rank = other.availableSpace;
               requirement = other.availableSpace > 5G && other.MaxRDBandwidth > 50K/Sec;"#,
        )
        .unwrap()
    });

    let stats = b.finish();
    // Sanity for EXPERIMENTS.md: match+rank over 1024 ads should beat
    // 10^5 ads/s single-thread (DESIGN.md §Perf target).
    if let Some(s) = stats.iter().find(|s| s.name.contains("1024")) {
        println!(
            "\nthroughput @1024 candidates: {:.0} ads/s (target ≥ 100000)",
            s.throughput()
        );
    }
}
