//! Co-allocation microbenches: stripe planning and scheduler
//! rebalancing on a 16-site topology, plus the end-to-end quality
//! comparison (single-best vs striped) the subsystem exists for.

use globus_replica::coalloc::{execute, plan_stripes, StripeSource};
use globus_replica::config::{CoallocPolicy, GridConfig};
use globus_replica::experiment::run_coalloc_quality;
use globus_replica::gridftp::GridFtp;
use globus_replica::simnet::{Topology, WorkloadSpec};
use globus_replica::util::bench::{report_metric, Bench};

fn main() {
    let cfg = GridConfig::generate(16, 4242);
    let policy = CoallocPolicy {
        block_size: 8.0 * 1024.0 * 1024.0,
        max_streams: 8,
        tick: 2.0,
        ..Default::default()
    };
    let sources: Vec<StripeSource> = cfg
        .sites
        .iter()
        .enumerate()
        .map(|(i, s)| StripeSource {
            site: s.name.clone(),
            url: format!("gsiftp://{}/f", s.name),
            predicted_bw: 100e3 * (i + 1) as f64,
        })
        .collect();

    let mut b = Bench::new("coalloc (16-site topology)");
    b.case("plan 1G file over 16 sources, k=8", || {
        plan_stripes(&sources, 1024.0 * 1024.0 * 1024.0, &policy).n_blocks
    });
    b.case("plan 64G file over 16 sources, k=16", || {
        let wide = CoallocPolicy { max_streams: 16, ..policy.clone() };
        plan_stripes(&sources, 64.0 * 1024f64.powi(3), &wide).n_blocks
    });

    // Scheduler: execute a 256 MB striped transfer on a fresh topology
    // clone each iteration (execution mutates link state). The skew in
    // predicted vs actual bandwidth forces rebalancing steals.
    let base_topo = Topology::build(&cfg);
    let plan = plan_stripes(&sources, 256.0 * 1024.0 * 1024.0, &policy);
    let mut total_steals = 0usize;
    let mut runs = 0usize;
    b.case("schedule+rebalance 256M, 8 streams", || {
        let mut topo = base_topo.clone_for_probe();
        let ftp = GridFtp::new(&topo, 32);
        let out = execute(&mut topo, &ftp, "bench-client", &plan, &policy).unwrap();
        total_steals += out.steals;
        runs += 1;
        out.duration
    });
    b.finish();
    if runs > 0 {
        report_metric(
            "mean rebalance steals per transfer",
            total_steals as f64 / runs as f64,
            "steals",
        );
    }

    // Domain-level comparison on the paper-scale grid.
    println!("\n== single-best vs co-allocated (16 sites, 4 replicas/file) ==");
    let spec = WorkloadSpec { files: 12, mean_interarrival: 120.0, ..Default::default() };
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_requests = if quick { 10 } else { 40 };
    let r = run_coalloc_quality(&cfg, &spec, n_requests, 4, 6, &policy);
    report_metric("requests", r.requests as f64, "");
    report_metric("mean single-best transfer time", r.single_mean_time, "s");
    report_metric("mean co-allocated transfer time", r.coalloc_mean_time, "s");
    report_metric("speedup (single / coalloc)", r.speedup, "x");
    report_metric("mean streams per transfer", r.mean_streams, "");
    report_metric("total rebalance steals", r.steals as f64, "");
}
