//! Co-allocation microbenches: stripe planning and scheduler
//! rebalancing on a 16-site topology, the failover path (steady state
//! vs one replica death at 50% of the predicted makespan), plus the
//! end-to-end quality comparisons (single-best vs striped; the churn
//! scenario) the subsystem exists for.
//!
//! With `BENCH_JSON=<path>` set, the case stats and the churn headline
//! numbers (completion rates and mean times per strategy) are written
//! as JSON — `scripts/bench.sh` uses this to record
//! `BENCH_coalloc.json` next to `BENCH_matchmaking.json`.

use std::collections::BTreeMap;

use globus_replica::coalloc::{execute, plan_stripes, StripeSource};
use globus_replica::config::{CoallocPolicy, GridConfig};
use globus_replica::experiment::{run_churn, run_coalloc_quality, ChurnStrategyReport};
use globus_replica::gridftp::GridFtp;
use globus_replica::metrics::Metrics;
use globus_replica::simnet::{FaultKind, Topology, WorkloadSpec};
use globus_replica::util::bench::{report_metric, Bench, Stats};
use globus_replica::util::json::Json;

fn churn_json(r: &ChurnStrategyReport) -> Json {
    let mut o = BTreeMap::new();
    o.insert("attempts".to_string(), Json::Num(r.attempts as f64));
    o.insert("completed".to_string(), Json::Num(r.completed as f64));
    o.insert("failed".to_string(), Json::Num(r.failed as f64));
    o.insert("mean_time_s".to_string(), Json::Num(r.mean_time));
    o.insert("failovers".to_string(), Json::Num(r.failovers as f64));
    o.insert("blocks_requeued".to_string(), Json::Num(r.blocks_requeued as f64));
    Json::Obj(o)
}

fn main() {
    let cfg = GridConfig::generate(16, 4242);
    let policy = CoallocPolicy {
        block_size: 8.0 * 1024.0 * 1024.0,
        max_streams: 8,
        tick: 2.0,
        ..Default::default()
    };
    let sources: Vec<StripeSource> = cfg
        .sites
        .iter()
        .enumerate()
        .map(|(i, s)| StripeSource {
            site: s.name.clone(),
            url: format!("gsiftp://{}/f", s.name),
            predicted_bw: 100e3 * (i + 1) as f64,
        })
        .collect();

    let mut b = Bench::new("coalloc (16-site topology)");
    b.case("plan 1G file over 16 sources, k=8", || {
        plan_stripes(&sources, 1024.0 * 1024.0 * 1024.0, &policy).n_blocks
    });
    b.case("plan 64G file over 16 sources, k=16", || {
        let wide = CoallocPolicy { max_streams: 16, ..policy.clone() };
        plan_stripes(&sources, 64.0 * 1024f64.powi(3), &wide).n_blocks
    });
    b.case("plan 1G, downlink-clipped to 1 MB/s", || {
        let capped = CoallocPolicy { client_downlink: 1e6, ..policy.clone() };
        plan_stripes(&sources, 1024.0 * 1024.0 * 1024.0, &capped)
            .assignments
            .len()
    });

    // Scheduler: execute a 256 MB striped transfer on a fresh topology
    // clone each iteration (execution mutates link state). The skew in
    // predicted vs actual bandwidth forces rebalancing steals.
    let base_topo = Topology::build(&cfg);
    let plan = plan_stripes(&sources, 256.0 * 1024.0 * 1024.0, &policy);
    let mut total_steals = 0usize;
    let mut runs = 0usize;
    b.case("schedule+rebalance 256M, 8 streams", || {
        let mut topo = base_topo.clone_for_probe();
        let ftp = GridFtp::new(&topo, 32);
        let out = execute(&mut topo, &ftp, "bench-client", &plan, &policy).unwrap();
        total_steals += out.steals;
        runs += 1;
        out.duration
    });

    // Failover path: identical transfer, steady state vs the plan's
    // largest stripe dying at 50% of the predicted makespan. The delta
    // between the two cases is the scheduler-side cost of absorbing a
    // death (detection, cancellation, re-queue, extra steals).
    let victim = plan
        .assignments
        .iter()
        .max_by(|a, b| a.share.partial_cmp(&b.share).unwrap())
        .map(|a| a.source.site.clone())
        .unwrap();
    let victim_idx = base_topo.index_of(&victim).unwrap();
    let death_at = plan.predicted_makespan() * 0.5;
    b.case("failover: steady state 256M, 8 streams", || {
        let mut topo = base_topo.clone_for_probe();
        let ftp = GridFtp::new(&topo, 32);
        execute(&mut topo, &ftp, "bench-client", &plan, &policy)
            .unwrap()
            .duration
    });
    let mut total_requeued = 0usize;
    let mut failover_runs = 0usize;
    b.case("failover: one death at 50%, 256M, 8 streams", || {
        let mut topo = base_topo.clone_for_probe();
        topo.schedule_fault(victim_idx, death_at, FaultKind::ReplicaDeath);
        let ftp = GridFtp::new(&topo, 32);
        let out = execute(&mut topo, &ftp, "bench-client", &plan, &policy).unwrap();
        total_requeued += out.blocks_requeued;
        failover_runs += 1;
        out.duration
    });
    let stats = b.finish();
    if runs > 0 {
        report_metric(
            "mean rebalance steals per transfer",
            total_steals as f64 / runs as f64,
            "steals",
        );
    }
    if failover_runs > 0 {
        report_metric(
            "mean blocks requeued per death",
            total_requeued as f64 / failover_runs as f64,
            "blocks",
        );
    }

    // Domain-level comparison on the paper-scale grid.
    println!("\n== single-best vs co-allocated (16 sites, 4 replicas/file) ==");
    let spec = WorkloadSpec { files: 12, mean_interarrival: 120.0, ..Default::default() };
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_requests = if quick { 10 } else { 40 };
    let r = run_coalloc_quality(&cfg, &spec, n_requests, 4, 6, &policy);
    report_metric("requests", r.requests as f64, "");
    report_metric("mean single-best transfer time", r.single_mean_time, "s");
    report_metric("mean co-allocated transfer time", r.coalloc_mean_time, "s");
    report_metric("speedup (single / coalloc)", r.speedup, "x");
    report_metric("mean streams per transfer", r.mean_streams, "");
    report_metric("total rebalance steals", r.steals as f64, "");

    // Churn scenario: what each Access strategy survives when the
    // predicted-best source dies halfway through (ISSUE 3).
    println!("\n== churn: predicted-best source dies at 50% of makespan ==");
    let churn_n = if quick { 6 } else { 20 };
    let churn = run_churn(&cfg, &spec, churn_n, 4, 6, &policy, 0.5);
    for s in churn.strategies() {
        println!(
            "{:<20} completed {:>3}/{:<3}  mean {:>8.1}s  failovers {:>3}  requeued {:>4}",
            s.strategy, s.completed, s.attempts, s.mean_time, s.failovers, s.blocks_requeued
        );
    }

    // One representative execution's counters routed through the
    // Metrics registry; the BENCH JSON embeds the full stable-ordered
    // `snapshot()` (P8) instead of bespoke counter printing.
    let m = Metrics::new();
    {
        let mut topo = base_topo.clone_for_probe();
        topo.schedule_fault(victim_idx, death_at, FaultKind::ReplicaDeath);
        let ftp = GridFtp::new(&topo, 32);
        let out = execute(&mut topo, &ftp, "bench-client", &plan, &policy).unwrap();
        out.record_metrics(&m);
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("coalloc".to_string()));
        root.insert(
            "cases".to_string(),
            Json::Arr(stats.iter().map(Stats::to_json).collect()),
        );
        let mut churn_obj = BTreeMap::new();
        churn_obj.insert("single_best".to_string(), churn_json(&churn.single_best));
        churn_obj.insert("striped".to_string(), churn_json(&churn.striped));
        churn_obj.insert(
            "striped_failover".to_string(),
            churn_json(&churn.striped_failover),
        );
        root.insert("churn_death_at_50pct".to_string(), Json::Obj(churn_obj));
        root.insert(
            "coalloc_speedup_vs_single_best".to_string(),
            Json::Num(r.speedup),
        );
        root.insert(
            "metrics".to_string(),
            Json::parse(&m.to_json()).expect("snapshot JSON parses"),
        );
        let body = Json::Obj(root).to_string();
        match std::fs::write(&path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
