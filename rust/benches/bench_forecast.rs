//! R6 — §3.2 history-based prediction: forecaster-bank accuracy vs
//! naive predictors, and kernel latency (pure-Rust bank vs the
//! AOT-compiled JAX/Pallas artifact through PJRT).

use globus_replica::forecast::forecast_bank;
use globus_replica::runtime::engine::EngineHandle;
use globus_replica::util::bench::{report_metric, Bench};
use globus_replica::util::prng::Rng;

/// AR(1) series: the regime the simulator produces and the forecaster
/// family targets.
fn ar1(rng: &mut Rng, n: usize, mean: f64, rho: f64, noise: f64) -> Vec<f64> {
    let mut x = 0.0;
    (0..n)
        .map(|_| {
            x = rho * x + rng.gauss(0.0, noise);
            (mean * (1.0 + x)).max(1.0)
        })
        .collect()
}

/// White noise around a mean.
fn white(rng: &mut Rng, n: usize, mean: f64, noise: f64) -> Vec<f64> {
    (0..n).map(|_| rng.gauss(mean, mean * noise).max(1.0)).collect()
}

/// Stable bandwidth with occasional congestion collapses.
fn spiky(rng: &mut Rng, n: usize, mean: f64) -> Vec<f64> {
    (0..n)
        .map(|_| {
            if rng.chance(0.1) {
                rng.range(mean * 0.02, mean * 0.1)
            } else {
                rng.gauss(mean, mean * 0.05).max(1.0)
            }
        })
        .collect()
}

fn main() {
    let mut rng = Rng::new(7);

    // --- Accuracy: one-step-ahead MSE, normalized per series, over a
    // *mixed* population of regimes (no single fixed predictor is best
    // everywhere — the point of NWS-style adaptive selection).
    println!("== forecast accuracy (paper §3.2; R6) — mixed bandwidth regimes ==");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>10}",
        "regime", "last-value", "run-mean", "adaptive", "adapt-wins"
    );
    let mut agg = [0.0f64; 3];
    let mut agg_n = 0.0;
    for (label, gen) in [
        ("ar1", 0usize),
        ("white-noise", 1),
        ("spiky", 2),
    ] {
        let mut errs = [0.0f64; 3];
        let mut n_evals = 0.0;
        for _ in 0..120 {
            let series = match gen {
                0 => ar1(&mut rng, 48, 500e3, 0.8, 0.15),
                1 => white(&mut rng, 48, 500e3, 0.2),
                _ => spiky(&mut rng, 48, 500e3),
            };
            let var = {
                let m = series.iter().sum::<f64>() / series.len() as f64;
                series.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / series.len() as f64
            };
            for t in 24..48 {
                let past = &series[..t];
                let mask = vec![1.0; past.len()];
                let bank = forecast_bank(past, &mask);
                let truth = series[t];
                // Normalize by series variance so regimes weigh equally.
                errs[0] += (bank.preds[0] - truth).powi(2) / var;
                errs[1] += (bank.preds[1] - truth).powi(2) / var;
                errs[2] += (bank.best() - truth).powi(2) / var;
                n_evals += 1.0;
            }
        }
        println!(
            "{label:<14} {:>12.4} {:>12.4} {:>12.4} {:>10}",
            errs[0] / n_evals,
            errs[1] / n_evals,
            errs[2] / n_evals,
            if errs[2] <= errs[0].min(errs[1]) * 1.05 { "yes" } else { "no" }
        );
        for i in 0..3 {
            agg[i] += errs[i];
        }
        agg_n += n_evals;
    }
    report_metric("aggregate nMSE, last-value", agg[0] / agg_n, "");
    report_metric("aggregate nMSE, running-mean", agg[1] / agg_n, "");
    report_metric("aggregate nMSE, adaptive bank", agg[2] / agg_n, "");
    report_metric(
        "adaptive vs best-fixed",
        agg[0].min(agg[1]) / agg[2],
        "x (>=1 = adaptive at least as good as any fixed predictor)",
    );

    // --- Latency: rust bank vs PJRT artifact --------------------------
    let mut b = Bench::new("forecast latency (R6)");
    let series64: Vec<Vec<f64>> = (0..512)
        .map(|_| ar1(&mut rng, 64, 500e3, 0.8, 0.15))
        .collect();
    let mask64 = vec![1.0; 64];
    b.case("rust bank, 1 site x 64 window", || {
        forecast_bank(&series64[0], &mask64).best()
    });
    for n in [8usize, 64, 128] {
        b.case_items(&format!("rust bank, {n} sites"), n as f64, || {
            series64[..n]
                .iter()
                .map(|s| forecast_bank(s, &mask64).best())
                .sum::<f64>()
        });
    }

    match EngineHandle::spawn_default() {
        Ok(engine) => {
            for n in [8usize, 64, 128, 512] {
                let hist = &series64[..n];
                let load = vec![0.0; n];
                b.case_items(&format!("pjrt artifact, {n} sites"), n as f64, || {
                    engine.forecast(hist, &load).unwrap().best.len()
                });
            }
        }
        Err(e) => println!("(pjrt cases skipped: {e:#})"),
    }
    b.finish();
}
