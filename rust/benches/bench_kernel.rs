//! Kernel throughput bench (ISSUE 8): events/second on the
//! allocation-free discrete-event kernel with 10⁵ transfers
//! simultaneously in flight, under the sharded control plane.
//!
//! Each point replays a day-of-traffic stress shape — a same-instant
//! surge to peak concurrency plus a trickle over the day — bounded by
//! an event budget (a full drain at 10⁵ flows is quadratic and not
//! what the bench certifies). The JSON asserts `peak_in_flight ≥
//! concurrent` so the headline events/sec number is honest about the
//! load it was measured under.
//!
//! With `BENCH_JSON=<path>` set, the sweep is written as JSON —
//! `scripts/bench.sh` uses this to record `BENCH_kernel.json` next to
//! the other perf artifacts. `BENCH_QUICK=1` shrinks the surge for
//! smoke runs.

use std::collections::BTreeMap;

use globus_replica::experiment::{run_kernel, KernelOptions, KernelReport, ShardOptions};
use globus_replica::metrics::Metrics;
use globus_replica::util::bench::report_metric;
use globus_replica::util::json::Json;

fn point_json(label: &str, shards: usize, r: &KernelReport) -> Json {
    let mut o = BTreeMap::new();
    o.insert("label".to_string(), Json::Str(label.to_string()));
    o.insert("shards".to_string(), Json::Num(shards as f64));
    o.insert("requests".to_string(), Json::Num(r.requests as f64));
    o.insert("concurrent".to_string(), Json::Num(r.concurrent as f64));
    o.insert("peak_in_flight".to_string(), Json::Num(r.peak_in_flight as f64));
    o.insert("events".to_string(), Json::Num(r.events as f64));
    o.insert("wall_s".to_string(), Json::Num(r.wall_s));
    o.insert("events_per_sec".to_string(), Json::Num(r.events_per_sec));
    o.insert("finished".to_string(), Json::Num(r.finished as f64));
    o.insert("skipped".to_string(), Json::Num(r.skipped as f64));
    o.insert(
        "cross_shard_selections".to_string(),
        Json::Num(r.cross_shard_selections as f64),
    );
    o.insert("flushes".to_string(), Json::Num(r.flushes as f64));
    Json::Obj(o)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    // The acceptance point: ≥ 10⁵ concurrent requests. Quick mode
    // shrinks the surge (CI smoke), the full run certifies the claim.
    let (surge, trickle, steady) = if quick {
        (5_000usize, 200usize, 500usize)
    } else {
        (100_000, 2_000, 2_000)
    };
    let points: Vec<(&str, usize, usize)> = vec![
        // (label, shards, batch_max)
        ("unbatched_1shard", 1, 1),
        ("sharded_8x64", 8, 64),
    ];

    println!("== kernel: day-of-traffic surge ({surge} concurrent, event-budgeted) ==");
    println!(
        "{:<18} {:>7} {:>10} {:>10} {:>9} {:>12}",
        "point", "shards", "peak", "events", "wall s", "events/sec"
    );
    let m = Metrics::new();
    let mut results: Vec<(String, usize, KernelReport)> = Vec::new();
    for (label, shards, batch_max) in points {
        let o = KernelOptions {
            surge,
            trickle,
            steady_events: steady,
            shard: ShardOptions { shards, batch_max, batch_window: 1.0 },
            ..Default::default()
        };
        let r = run_kernel(&o);
        println!(
            "{:<18} {:>7} {:>10} {:>10} {:>9.2} {:>12.0}",
            label, shards, r.peak_in_flight, r.events, r.wall_s, r.events_per_sec
        );
        assert!(
            r.peak_in_flight >= r.concurrent,
            "{label}: surge not fully concurrent ({} < {})",
            r.peak_in_flight,
            r.concurrent
        );
        m.counter("kernel.events").add(r.events as u64);
        m.histogram("kernel.wall_ns")
            .observe(std::time::Duration::from_secs_f64(r.wall_s));
        results.push((label.to_string(), shards, r));
    }
    if let Some((_, _, last)) = results.last() {
        report_metric("kernel events/sec (sharded)", last.events_per_sec, "ev/s");
        report_metric("peak concurrent transfers", last.peak_in_flight as f64, "");
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("kernel".to_string()));
        root.insert("concurrent".to_string(), Json::Num(surge as f64));
        root.insert("quick".to_string(), Json::Bool(quick));
        root.insert(
            "points".to_string(),
            Json::Arr(
                results
                    .iter()
                    .map(|(label, shards, r)| point_json(label, *shards, r))
                    .collect(),
            ),
        );
        root.insert(
            "metrics".to_string(),
            Json::parse(&m.to_json()).expect("snapshot JSON parses"),
        );
        let body = Json::Obj(root).to_string();
        match std::fs::write(&path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
