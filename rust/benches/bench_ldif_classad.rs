//! R2 — the paper's §6 claim: "the process of converting data,
//! represented in LDAP format, into ClassAds is not cumbersome and is
//! worth the effort."
//!
//! Measures LDIF parse, Entry→ClassAd conversion, and the combined
//! pipeline at increasing batch sizes, plus the serialize direction.

use globus_replica::broker::entries_to_candidate;
use globus_replica::directory::entry::{Dn, Entry};
use globus_replica::directory::ldif::{parse_ldif, to_ldif_stream};
use globus_replica::util::bench::Bench;
use globus_replica::util::prng::Rng;

fn site_entries(site: usize, rng: &mut Rng) -> Vec<Entry> {
    let base = Dn::parse(&format!("ou=s{site}, o=org, o=grid")).unwrap();
    let vol = base.child("gss", "vol0");
    let mut e = Entry::new(vol.clone());
    e.add("objectClass", "GridStorageServerVolume");
    e.put_f64("totalSpace", rng.range(1e10, 2e11));
    e.put_f64("availableSpace", rng.range(1e9, 1e11));
    e.put("mountPoint", "/data");
    e.put_f64("diskTransferRate", 2e7);
    e.put_f64("drdTime", 8.5);
    e.put_f64("dwrTime", 9.5);
    e.put(
        "requirements",
        "other.reqdSpace < 10G && other.reqdRDBandwidth < 75K/Sec",
    );
    let mut bw = Entry::new(vol.child("gss", "bw"));
    bw.add("objectClass", "GridStorageTransferBandwidth");
    for a in [
        "MaxRDBandwidth",
        "MinRDBandwidth",
        "AvgRDBandwidth",
        "MaxWRBandwidth",
        "MinWRBandwidth",
        "AvgWRBandwidth",
    ] {
        bw.put_f64(a, rng.range(1e4, 1e6));
    }
    let mut src = Entry::new(vol.child("gss", "src"));
    src.add("objectClass", "GridStorageSourceTransferBandwidth");
    src.put_f64("lastRDBandwidth", rng.range(1e4, 1e6));
    src.put("lastRDurl", "gsiftp://client/");
    src.put_f64("lastWRBandwidth", rng.range(1e4, 1e6));
    src.put("lastWRurl", "gsiftp://client/");
    let hist: Vec<String> = (0..32).map(|_| format!("{:.0}", rng.range(1e4, 1e6))).collect();
    src.put("rdHistory", hist.join(","));
    vec![e, bw, src]
}

fn main() {
    let mut rng = Rng::new(2026);
    let mut b = Bench::new("LDIF -> ClassAd conversion (paper §6; R2)");

    let one = site_entries(0, &mut rng);
    let one_ldif = to_ldif_stream(&one);
    b.case("serialize 1 site (3 entries) to LDIF", || to_ldif_stream(&one));
    b.case("parse 1 site LDIF", || parse_ldif(&one_ldif).unwrap());
    b.case("convert 1 site entries -> ClassAd", || {
        entries_to_candidate("s0", "gsiftp://s0/f", &one)
    });
    b.case("full pipeline: LDIF text -> Candidate", || {
        let entries = parse_ldif(&one_ldif).unwrap();
        entries_to_candidate("s0", "gsiftp://s0/f", &entries)
    });

    for n in [8usize, 64, 512] {
        let sites: Vec<Vec<Entry>> = (0..n).map(|i| site_entries(i, &mut rng)).collect();
        let ldifs: Vec<String> = sites.iter().map(|e| to_ldif_stream(e)).collect();
        b.case_items(&format!("convert {n} sites (LDIF->ClassAd)"), n as f64, || {
            ldifs
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    let entries = parse_ldif(l).unwrap();
                    entries_to_candidate(&format!("s{i}"), "u", &entries)
                })
                .count()
        });
    }

    b.finish();
}
