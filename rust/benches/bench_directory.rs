//! R4 — directory query cost (paper §3/§5.1.2 search phase): GRIS
//! searches with dynamic providers, GIIS discovery at scale, and the
//! full TCP round trip a deployed broker pays.

use std::sync::{Arc, Mutex};

use globus_replica::directory::client::DirectoryClient;
use globus_replica::directory::server::DirectoryServer;
use globus_replica::directory::{Dn, Entry, Filter, Giis, Gris, Scope};
use globus_replica::util::bench::Bench;
use globus_replica::util::prng::Rng;

fn demo_gris(volumes: usize) -> Gris {
    let mut rng = Rng::new(5);
    let mut gris = Gris::new("anl", "mcs");
    let base = gris.base_dn().clone();
    for v in 0..volumes {
        let vol = base.child("gss", &format!("vol{v}"));
        let mut e = Entry::new(vol.clone());
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("totalSpace", rng.range(1e10, 2e11));
        e.put_f64("availableSpace", rng.range(1e9, 1e11));
        e.put("mountPoint", format!("/data{v}"));
        e.put_f64("diskTransferRate", 2e7);
        e.put_f64("drdTime", 8.0);
        e.put_f64("dwrTime", 9.0);
        gris.add_entry(e);
        // A dynamic provider per volume (the shell-backend analog).
        gris.add_provider(
            &vol,
            Arc::new(move || vec![("load".into(), format!("{:.3}", (v % 10) as f64 / 10.0))]),
        );
    }
    gris
}

fn main() {
    let mut b = Bench::new("directory / MDS (paper §3; R4)");
    let root = Dn::parse("o=grid").unwrap();
    let f_all = Filter::parse("(objectClass=GridStorageServerVolume)").unwrap();
    let f_sel = Filter::parse("(&(objectClass=GridStorage*)(availableSpace>=5000000000))").unwrap();

    for volumes in [1usize, 8, 64] {
        let gris = demo_gris(volumes);
        b.case_items(
            &format!("GRIS search sub, {volumes} volumes, w/ providers"),
            volumes as f64,
            || gris.search(&root, Scope::Sub, &f_all).len(),
        );
        b.case_items(
            &format!("GRIS filtered search, {volumes} volumes"),
            volumes as f64,
            || gris.search(&root, Scope::Sub, &f_sel).len(),
        );
        // Generation-cached materialization: repeated broker fan-outs
        // against an unchanged site skip the provider-run + merge cost.
        let mut cached = demo_gris(volumes);
        cached.set_cache_ttl(Some(f64::INFINITY));
        b.case_items(
            &format!("GRIS search sub, {volumes} volumes, cached"),
            volumes as f64,
            || cached.search(&root, Scope::Sub, &f_all).len(),
        );
    }

    // GIIS discovery at increasing registration counts.
    for sites in [16usize, 256, 2048] {
        let mut giis = Giis::new();
        for s in 0..sites {
            giis.register(
                &format!("site{s}"),
                &format!("10.0.0.{}:9000", s % 250),
                Dn::parse(&format!("ou=s{s}, o=grid")).unwrap(),
                vec![
                    ("storageType".into(), if s % 3 == 0 { "tape" } else { "disk" }.into()),
                    ("availableGB".into(), format!("{}", s % 100)),
                ],
            );
        }
        let f = Filter::parse("(&(storageType=disk)(availableGB>=50))").unwrap();
        b.case_items(&format!("GIIS discover over {sites} regs"), sites as f64, || {
            giis.discover(&f).len()
        });
    }

    // The wire: full TCP search round trip (connect once, query many).
    let server =
        DirectoryServer::spawn(Arc::new(Mutex::new(demo_gris(8))), 0).expect("bind");
    let mut client = DirectoryClient::connect(server.addr()).expect("connect");
    b.case("TCP GRIS search round trip (8 volumes)", || {
        client.search(&root, Scope::Sub, &f_all).unwrap().len()
    });
    let mut giis_net = Giis::new();
    giis_net.register("mcs", server.addr(), Dn::parse("ou=mcs, o=grid").unwrap(), vec![]);
    let giis_srv = DirectoryServer::spawn(Arc::new(Mutex::new(giis_net)), 0).expect("bind");
    let mut gc = DirectoryClient::connect(giis_srv.addr()).expect("connect");
    b.case("TCP GIIS list round trip", || gc.list().unwrap().len());
    b.case("TCP connect+search+close", || {
        let mut c = DirectoryClient::connect(server.addr()).unwrap();
        c.search(&root, Scope::Sub, &f_all).unwrap().len()
    });

    b.finish();
}
