//! R4 — directory query cost (paper §3/§5.1.2 search phase): GRIS
//! searches with dynamic providers, GIIS discovery at scale, the full
//! TCP round trip a deployed broker pays, and (ISSUE 5) selection at
//! hundreds of sites — GIIS-routed drill-down vs the direct full
//! fan-out, plus the event-driven fan-out kernel drive.
//!
//! With `BENCH_JSON=<path>` set, the headline numbers (per-case stats,
//! the GIIS-vs-direct speedup at 256 sites × 32 replicas, and the
//! per-select query economy) are written as JSON — `scripts/bench.sh`
//! records this as `BENCH_directory.json`.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use globus_replica::broker::RankPolicy;
use globus_replica::classad::parse_classad;
use globus_replica::config::GridConfig;
use globus_replica::directory::client::DirectoryClient;
use globus_replica::directory::fanout::{run_fanout_on, FanoutPolicy};
use globus_replica::directory::server::DirectoryServer;
use globus_replica::directory::{Dn, Entry, Filter, Giis, Gris, Scope};
use globus_replica::experiment::SimGrid;
use globus_replica::simnet::{Topology, WorkloadSpec};
use globus_replica::util::bench::{Bench, Stats};
use globus_replica::util::json::Json;
use globus_replica::util::prng::Rng;

fn demo_gris(volumes: usize) -> Gris {
    let mut rng = Rng::new(5);
    let mut gris = Gris::new("anl", "mcs");
    let base = gris.base_dn().clone();
    for v in 0..volumes {
        let vol = base.child("gss", &format!("vol{v}"));
        let mut e = Entry::new(vol.clone());
        e.add("objectClass", "GridStorageServerVolume");
        e.put_f64("totalSpace", rng.range(1e10, 2e11));
        e.put_f64("availableSpace", rng.range(1e9, 1e11));
        e.put("mountPoint", format!("/data{v}"));
        e.put_f64("diskTransferRate", 2e7);
        e.put_f64("drdTime", 8.0);
        e.put_f64("dwrTime", 9.0);
        gris.add_entry(e);
        // A dynamic provider per volume (the shell-backend analog).
        gris.add_provider(
            &vol,
            Arc::new(move || vec![("load".into(), format!("{:.3}", (v % 10) as f64 / 10.0))]),
        );
    }
    gris
}

fn main() {
    let mut b = Bench::new("directory / MDS (paper §3; R4)");
    let root = Dn::parse("o=grid").unwrap();
    let f_all = Filter::parse("(objectClass=GridStorageServerVolume)").unwrap();
    let f_sel = Filter::parse("(&(objectClass=GridStorage*)(availableSpace>=5000000000))").unwrap();

    for volumes in [1usize, 8, 64] {
        let gris = demo_gris(volumes);
        b.case_items(
            &format!("GRIS search sub, {volumes} volumes, w/ providers"),
            volumes as f64,
            || gris.search(&root, Scope::Sub, &f_all).len(),
        );
        b.case_items(
            &format!("GRIS filtered search, {volumes} volumes"),
            volumes as f64,
            || gris.search(&root, Scope::Sub, &f_sel).len(),
        );
        // Generation-cached materialization: repeated broker fan-outs
        // against an unchanged site skip the provider-run + merge cost.
        let mut cached = demo_gris(volumes);
        cached.set_cache_ttl(Some(f64::INFINITY));
        b.case_items(
            &format!("GRIS search sub, {volumes} volumes, cached"),
            volumes as f64,
            || cached.search(&root, Scope::Sub, &f_all).len(),
        );
    }

    // GIIS discovery at increasing registration counts.
    for sites in [16usize, 256, 2048] {
        let mut giis = Giis::new();
        for s in 0..sites {
            giis.register(
                &format!("site{s}"),
                &format!("10.0.0.{}:9000", s % 250),
                Dn::parse(&format!("ou=s{s}, o=grid")).unwrap(),
                vec![
                    ("storageType".into(), if s % 3 == 0 { "tape" } else { "disk" }.into()),
                    ("availableGB".into(), format!("{}", s % 100)),
                ],
            );
        }
        let f = Filter::parse("(&(storageType=disk)(availableGB>=50))").unwrap();
        b.case_items(&format!("GIIS discover over {sites} regs"), sites as f64, || {
            giis.discover(&f).len()
        });
    }

    // The wire: full TCP search round trip (connect once, query many).
    let server =
        DirectoryServer::spawn(Arc::new(Mutex::new(demo_gris(8))), 0).expect("bind");
    let mut client = DirectoryClient::connect(server.addr()).expect("connect");
    b.case("TCP GRIS search round trip (8 volumes)", || {
        client.search(&root, Scope::Sub, &f_all).unwrap().len()
    });
    let mut giis_net = Giis::new();
    giis_net.register("mcs", server.addr(), Dn::parse("ou=mcs, o=grid").unwrap(), vec![]);
    let giis_srv = DirectoryServer::spawn(Arc::new(Mutex::new(giis_net)), 0).expect("bind");
    let mut gc = DirectoryClient::connect(giis_srv.addr()).expect("connect");
    b.case("TCP GIIS list round trip", || gc.list().unwrap().len());
    b.case("TCP connect+search+close", || {
        let mut c = DirectoryClient::connect(server.addr()).unwrap();
        c.search(&root, Scope::Sub, &f_all).unwrap().len()
    });

    // ISSUE 5 — discovery at hundreds of sites, on a live SimGrid
    // (dynamic providers, history feeds): the direct route queries
    // every replica site's GRIS per selection; the GIIS route pays one
    // broad soft-state lookup plus K drill-downs.
    let n_sites = 256usize;
    let replicas = 32usize;
    let drill = 4usize;
    let cfg = GridConfig::generate(n_sites, 42);
    let spec = WorkloadSpec { files: 4, ..Default::default() };
    let mut grid = SimGrid::build(&cfg, &spec, replicas, 64);
    grid.warm(2);
    let req = parse_classad("reqdSpace = 0; requirement = TRUE;").unwrap();
    let direct = grid.broker(RankPolicy::ForecastBandwidth { engine: None });
    let dir = grid.hierarchy(f64::INFINITY);
    let hier = grid.broker_hier(RankPolicy::ForecastBandwidth { engine: None }, dir, drill);
    let logical = grid.files[0].clone();
    let s_direct = b
        .case(
            &format!("direct select, {n_sites} sites × {replicas} replicas"),
            || direct.select(&logical, &req).unwrap().ranked.len(),
        )
        .clone();
    let s_hier = b
        .case(
            &format!("GIIS-routed select, drill {drill}"),
            || hier.select(&logical, &req).unwrap().ranked.len(),
        )
        .clone();
    // Sanity: the two routes agree on the winner under fresh soft
    // state, and the query bills differ as designed.
    let a = direct.select(&logical, &req).unwrap();
    let h = hier.select(&logical, &req).unwrap();
    assert_eq!(a.site, h.site, "fresh-registration parity");
    let full_queries = a.candidates.len();
    let hier_queries = h.trace.drill_downs;
    assert!(hier_queries < full_queries);

    // The event-driven fan-out kernel drive at hundreds of sites. One
    // scratch clock topology reused across iterations, so the measured
    // loop is the engine drive itself, not scratch setup.
    let sites: Vec<(usize, f64)> = (0..n_sites)
        .map(|i| (i, grid.topo.site(i).cfg.latency * 2.0))
        .collect();
    let mut scratch = Topology::build(&GridConfig::generate(1, 0));
    b.case(&format!("event-driven fanout drive, {n_sites} queries"), || {
        let now = scratch.now;
        run_fanout_on(
            &mut scratch,
            now,
            &sites,
            FanoutPolicy { max_in_flight: 16, ..Default::default() },
        )
        .responses()
        .len()
    });

    let stats = b.finish();
    let speedup = if s_hier.mean_ns > 0.0 { s_direct.mean_ns / s_hier.mean_ns } else { 0.0 };
    println!(
        "\nGIIS-routed vs direct @{n_sites} sites × {replicas} replicas: {speedup:.2}x \
         ({hier_queries} drill-downs vs {full_queries} site queries per select)"
    );
    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("directory".to_string()));
        root.insert(
            "cases".to_string(),
            Json::Arr(stats.iter().map(Stats::to_json).collect()),
        );
        // Key carries the measured geometry so retuning n_sites /
        // replicas can't silently relabel the perf trajectory.
        root.insert(
            format!("giis_vs_direct_speedup_{n_sites}x{replicas}"),
            Json::Num(speedup),
        );
        root.insert("sites".to_string(), Json::Num(n_sites as f64));
        root.insert("replicas_per_file".to_string(), Json::Num(replicas as f64));
        root.insert(
            "drill_queries_per_select".to_string(),
            Json::Num(hier_queries as f64),
        );
        root.insert(
            "full_fanout_queries_per_select".to_string(),
            Json::Num(full_queries as f64),
        );
        let body = Json::Obj(root).to_string();
        match std::fs::write(&path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
