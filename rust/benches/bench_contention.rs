//! Load-sweep bench for the open-loop runtime (ISSUE 4): arrival rate
//! from idle to saturation, informed (Forecast) vs uninformed (Random)
//! selection on identical traces — the Figure-style result the serial
//! replay could never produce.
//!
//! With `BENCH_JSON=<path>` set, every sweep point's headline numbers
//! (mean/p95 time, makespan, overlap counters, informed-vs-uninformed
//! gap) are written as JSON — `scripts/bench.sh` uses this to record
//! `BENCH_contention.json` next to the other perf artifacts.

use std::collections::BTreeMap;
use std::time::Instant;

use globus_replica::config::GridConfig;
use globus_replica::experiment::{run_contention, ContentionPoint, OpenLoopOptions, OpenReport};
use globus_replica::metrics::Metrics;
use globus_replica::simnet::WorkloadSpec;
use globus_replica::util::bench::report_metric;
use globus_replica::util::json::Json;

fn side_json(r: &OpenReport) -> Json {
    let mut o = BTreeMap::new();
    o.insert("requests".to_string(), Json::Num(r.quality.requests as f64));
    o.insert("mean_time_s".to_string(), Json::Num(r.quality.mean_time));
    o.insert("p95_time_s".to_string(), Json::Num(r.quality.p95_time));
    o.insert(
        "mean_bandwidth".to_string(),
        Json::Num(r.quality.mean_bandwidth),
    );
    o.insert("pct_optimal".to_string(), Json::Num(r.quality.pct_optimal));
    o.insert("makespan_s".to_string(), Json::Num(r.makespan));
    o.insert(
        "peak_in_flight".to_string(),
        Json::Num(r.peak_in_flight as f64),
    );
    o.insert(
        "overlapped_admissions".to_string(),
        Json::Num(r.overlapped_admissions as f64),
    );
    Json::Obj(o)
}

fn point_json(p: &ContentionPoint) -> Json {
    let mut o = BTreeMap::new();
    o.insert(
        "mean_interarrival_s".to_string(),
        Json::Num(p.mean_interarrival),
    );
    o.insert("informed".to_string(), side_json(&p.informed));
    o.insert("uninformed".to_string(), side_json(&p.uninformed));
    o.insert("gap_uninformed_over_informed".to_string(), Json::Num(p.gap));
    Json::Obj(o)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let cfg = GridConfig::generate(12, 777);
    let spec = WorkloadSpec { files: 16, ..Default::default() };
    let n_requests = if quick { 12 } else { 40 };
    // Mean inter-arrival sweep: idle → busy → saturated (≥ 3 points,
    // per the ISSUE-4 acceptance criteria).
    let rates: &[f64] = &[240.0, 60.0, 15.0];
    let opts = OpenLoopOptions::open();

    println!("== contention: open-loop load sweep (12 sites, {n_requests} requests/point) ==");
    let t0 = Instant::now();
    let sweep = run_contention(&cfg, &spec, n_requests, 4, 6, rates, &opts);
    let wall = t0.elapsed();

    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8} {:>7}",
        "interarrival", "inf mean", "inf p95", "uninf mean", "makespan", "peak", "overlap", "gap"
    );
    for p in &sweep.points {
        println!(
            "{:<14} {:>9.1}s {:>9.1}s {:>9.1}s {:>9.1}s {:>8} {:>8} {:>6.2}x",
            format!("{}s", p.mean_interarrival),
            p.informed.quality.mean_time,
            p.informed.quality.p95_time,
            p.uninformed.quality.mean_time,
            p.informed.makespan,
            p.informed.peak_in_flight,
            p.informed.overlapped_admissions,
            p.gap
        );
    }
    report_metric("sweep wall time", wall.as_secs_f64(), "s");
    if let Some(busiest) = sweep.points.last() {
        report_metric(
            "informed-vs-uninformed gap at saturation",
            busiest.gap,
            "x",
        );
        report_metric(
            "peak transfers in flight at saturation",
            busiest.informed.peak_in_flight as f64,
            "",
        );
    }

    // Aggregate counters and latency distributions go through the
    // Metrics registry and are serialized in one stable-ordered
    // `snapshot()` pass (P8) instead of bespoke per-field printing.
    let m = Metrics::new();
    m.counter("contention.points").add(sweep.points.len() as u64);
    m.counter("contention.requests_per_point").add(n_requests as u64);
    m.histogram("contention.sweep_wall_ns").observe(wall);
    for p in &sweep.points {
        m.histogram("contention.informed_mean_time_ns")
            .observe_ns((p.informed.quality.mean_time * 1e9) as u64);
        m.histogram("contention.informed_p95_time_ns")
            .observe_ns((p.informed.quality.p95_time * 1e9) as u64);
        m.counter("contention.overlapped_admissions")
            .add(p.informed.overlapped_admissions as u64);
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("contention".to_string()));
        root.insert(
            "requests_per_point".to_string(),
            Json::Num(n_requests as f64),
        );
        root.insert(
            "points".to_string(),
            Json::Arr(sweep.points.iter().map(point_json).collect()),
        );
        root.insert(
            "metrics".to_string(),
            Json::parse(&m.to_json()).expect("snapshot JSON parses"),
        );
        let body = Json::Obj(root).to_string();
        match std::fs::write(&path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
