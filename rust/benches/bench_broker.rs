//! R5 — broker phase breakdown (Figure 6) and the §5.1.1
//! decentralized-vs-centralized scalability comparison.
//!
//! Prints: (a) Search / Convert / Match latency split vs replica count,
//! (b) end-to-end selection latency, (c) virtual-time mean decision
//! latency vs offered concurrency for one central manager vs per-client
//! brokers (the paper's scalability argument, quantified).

use globus_replica::broker::centralized::{
    queueing_latencies_central, queueing_latencies_decentralized,
};
use globus_replica::broker::{RankPolicy, SelectScratch};
use globus_replica::classad::parse_classad;
use globus_replica::config::GridConfig;
use globus_replica::experiment::SimGrid;
use globus_replica::simnet::WorkloadSpec;
use globus_replica::util::bench::{report_metric, Bench};

fn main() {
    let mut b = Bench::new("broker phases (Figure 6; R5)");
    let request = parse_classad(
        r#"reqdSpace = 0; reqdRDBandwidth = 10K/Sec;
           rank = other.availableSpace;
           requirement = other.AvgRDBandwidth > 10K/Sec;"#,
    )
    .unwrap();

    let mut service_s_8 = 0.0;
    for sites in [4usize, 8, 32, 128] {
        let cfg = GridConfig::generate(sites, 42);
        let spec = WorkloadSpec { files: 4, ..Default::default() };
        // Every file on every site so candidate count == sites.
        let mut grid = SimGrid::build(&cfg, &spec, sites, 32);
        grid.warm(4);
        let logical = grid.files[0].clone();
        let broker = grid.broker(RankPolicy::ClassAdRank);
        let s = b.case_items(&format!("select e2e, {sites} replicas"), sites as f64, || {
            broker.select(&logical, &request).unwrap().site.len()
        });
        if sites == 8 {
            service_s_8 = s.mean_ns / 1e9;
        }
        // The match-many path: request compiled once, scratch reused.
        let prepared = broker.prepare(&request);
        let mut scratch = SelectScratch::default();
        b.case_items(
            &format!("select prepared e2e, {sites} replicas"),
            sites as f64,
            || {
                broker
                    .select_prepared(&logical, &prepared, &mut scratch)
                    .unwrap()
                    .site
                    .len()
            },
        );
        // Phase split from the trace of one selection.
        let sel = broker.select(&logical, &request).unwrap();
        println!(
            "    phase split {sites} replicas: search {}µs | convert {}µs | match {}µs",
            sel.trace.search_us, sel.trace.convert_us, sel.trace.match_us
        );
        // Forecast policy comparison at the same size.
        let fbroker = grid.broker(RankPolicy::ForecastBandwidth { engine: None });
        b.case_items(
            &format!("select e2e forecast-rank, {sites} replicas"),
            sites as f64,
            || fbroker.select(&logical, &request).unwrap().site.len(),
        );
    }
    b.finish();

    // §5.1.1 scalability: virtual-time queueing with the *measured*
    // decision service time (8-replica broker).
    println!("\n== decentralized vs centralized (paper §5.1.1) ==");
    println!("service time per decision: {:.1}µs", service_s_8 * 1e6);
    println!(
        "{:>12} {:>16} {:>16} {:>8}",
        "clients", "central mean", "decentral mean", "ratio"
    );
    for clients in [1usize, 2, 4, 8, 16, 32, 64] {
        // All clients decide within one decision window (worst case the
        // paper worries about).
        let arrivals = vec![0.0; clients];
        let client_of: Vec<usize> = (0..clients).collect();
        let c = queueing_latencies_central(&arrivals, service_s_8);
        let d = queueing_latencies_decentralized(&arrivals, service_s_8, &client_of, clients);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        println!(
            "{clients:>12} {:>14.1}µs {:>14.1}µs {:>8.1}",
            mean(&c) * 1e6,
            mean(&d) * 1e6,
            mean(&c) / mean(&d)
        );
    }
    report_metric(
        "\nselection overhead vs access phase",
        service_s_8 * 1e3,
        "ms per decision (compare: simulated transfers take seconds-minutes)",
    );
}
