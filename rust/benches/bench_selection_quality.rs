//! R7 — the headline experiment: selection quality of the informed
//! broker vs the uninformed baselines on the simulated grid, across
//! heterogeneity levels and replica counts.
//!
//! The paper's qualitative claims, quantified:
//! * informed (history-ranked) selection beats random/round-robin;
//! * history-based ranking beats ranking by static attributes
//!   (availableSpace) — the §3.2 motivation;
//! * the gap grows with site heterogeneity and with replica count
//!   (more choices → more to gain from choosing well).

use globus_replica::broker::selectors::SelectorKind;
use globus_replica::config::GridConfig;
use globus_replica::experiment::run_quality;
use globus_replica::simnet::WorkloadSpec;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok()
}

fn main() {
    let requests = if quick() { 60 } else { 250 };
    let warm = 10;

    println!("== selection quality (R7): {requests} requests/policy ==");
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "policy", "mean(s)", "p95(s)", "mean KB/s", "%optimal", "slowdown"
    );
    let cfg = GridConfig::generate(12, 42);
    let spec = WorkloadSpec { files: 24, ..Default::default() };
    let mut base_mean = None;
    let mut forecast_mean = None;
    for kind in SelectorKind::all() {
        let r = run_quality(&cfg, &spec, requests, 4, warm, kind, None);
        println!(
            "{:<16} {:>10.1} {:>10.1} {:>12.0} {:>9.0}% {:>10.2}",
            r.policy,
            r.mean_time,
            r.p95_time,
            r.mean_bandwidth / 1024.0,
            r.pct_optimal * 100.0,
            r.mean_slowdown
        );
        if kind == SelectorKind::Random {
            base_mean = Some(r.mean_time);
        }
        if kind == SelectorKind::Forecast {
            forecast_mean = Some(r.mean_time);
        }
    }
    println!(
        "\nheadline speedup forecast vs random: {:.2}x",
        base_mean.unwrap() / forecast_mean.unwrap()
    );

    // Sweep: replica count (choices per request).
    println!("\n== speedup vs replica count ==");
    println!("{:>10} {:>12} {:>12} {:>8}", "replicas", "random(s)", "forecast(s)", "speedup");
    for replicas in [2usize, 4, 8] {
        let rnd = run_quality(&cfg, &spec, requests / 2, replicas, warm, SelectorKind::Random, None);
        let fc = run_quality(&cfg, &spec, requests / 2, replicas, warm, SelectorKind::Forecast, None);
        println!(
            "{replicas:>10} {:>12.1} {:>12.1} {:>8.2}",
            rnd.mean_time,
            fc.mean_time,
            rnd.mean_time / fc.mean_time
        );
    }

    // Sweep: heterogeneity (same mean bandwidth, growing spread).
    println!("\n== speedup vs site heterogeneity ==");
    println!("{:>14} {:>12} {:>12} {:>8}", "spread", "random(s)", "forecast(s)", "speedup");
    for (label, squeeze) in [("low (1.5x)", 0.15), ("med (4x)", 0.55), ("high (20x)", 1.0)] {
        let mut c = GridConfig::generate(12, 77);
        // Compress log-spread of wan_bandwidth toward the geometric mean.
        let logs: Vec<f64> = c.sites.iter().map(|s| s.wan_bandwidth.ln()).collect();
        let mean_log = logs.iter().sum::<f64>() / logs.len() as f64;
        for (s, l) in c.sites.iter_mut().zip(&logs) {
            s.wan_bandwidth = (mean_log + (l - mean_log) * squeeze).exp();
        }
        let rnd = run_quality(&c, &spec, requests / 2, 4, warm, SelectorKind::Random, None);
        let fc = run_quality(&c, &spec, requests / 2, 4, warm, SelectorKind::Forecast, None);
        println!(
            "{label:>14} {:>12.1} {:>12.1} {:>8.2}",
            rnd.mean_time,
            fc.mean_time,
            rnd.mean_time / fc.mean_time
        );
    }
}
