//! Replica-economy bench (ISSUE 10): static placement vs the
//! popularity-driven economy on identical demand traces — the
//! placement headline. Each scenario (flash crowd, diurnal region
//! shift, cold start) replays the same requests twice; the
//! hit-rate-at-nearest-replica and mean-time gaps between the arms —
//! priced in `bytes_moved` of background replication traffic — are the
//! numbers the PR exists to move.
//!
//! With `BENCH_JSON=<path>` set, every point's per-arm headline numbers
//! are written as JSON — `scripts/bench.sh` uses this to record
//! `BENCH_economy.json` next to the other perf artifacts.

use std::collections::BTreeMap;
use std::time::Instant;

use globus_replica::config::GridConfig;
use globus_replica::experiment::{run_economy, EconomyArm, EconomySweepOptions};
use globus_replica::metrics::Metrics;
use globus_replica::simnet::WorkloadSpec;
use globus_replica::util::bench::report_metric;
use globus_replica::util::json::Json;

fn arm_json(a: &EconomyArm) -> Json {
    let mut o = BTreeMap::new();
    o.insert("mean_time_s".to_string(), Json::Num(a.mean_time));
    o.insert("p95_time_s".to_string(), Json::Num(a.p95));
    o.insert("completion_rate".to_string(), Json::Num(a.completion_rate));
    o.insert("hit_rate_nearest".to_string(), Json::Num(a.hit_rate_nearest));
    o.insert("bytes_moved".to_string(), Json::Num(a.bytes_moved));
    o.insert("replicas_created".to_string(), Json::Num(a.replicas_created as f64));
    o.insert("evictions".to_string(), Json::Num(a.evictions as f64));
    o.insert("failed_pushes".to_string(), Json::Num(a.failed_pushes as f64));
    Json::Obj(o)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let cfg = GridConfig::generate(10, 1010);
    let spec = WorkloadSpec { files: 12, mean_interarrival: 8.0, ..Default::default() };
    let n_requests = if quick { 20 } else { 60 };
    let opts = EconomySweepOptions::default();

    println!("== economy: placement sweep (10 sites, {n_requests} requests/arm, 2 arms/point) ==");
    let t0 = Instant::now();
    let report = run_economy(&cfg, &spec, n_requests, 2, 4, &opts);
    let wall = t0.elapsed();

    println!(
        "{:<14} | {:>9} {:>9} | {:>9} {:>9} | {:>9} {:>6} {:>6}",
        "scenario", "st hit", "ec hit", "st mean", "ec mean", "moved MB", "repl", "evict"
    );
    for p in &report.points {
        println!(
            "{:<14} | {:>8.0}% {:>8.0}% | {:>8.1}s {:>8.1}s | {:>9.1} {:>6} {:>6}",
            p.label,
            p.static_placement.hit_rate_nearest * 100.0,
            p.economy.hit_rate_nearest * 100.0,
            p.static_placement.mean_time,
            p.economy.mean_time,
            p.economy.bytes_moved / 1e6,
            p.economy.replicas_created,
            p.economy.evictions,
        );
    }
    report_metric("sweep wall time", wall.as_secs_f64(), "s");
    if let Some(flash) = report.points.first() {
        report_metric(
            "economy-over-static nearest-hit gain at flash crowd",
            flash.economy.hit_rate_nearest - flash.static_placement.hit_rate_nearest,
            "",
        );
        report_metric(
            "economy mean-time ratio at flash crowd (lower is better)",
            if flash.static_placement.mean_time > 0.0 {
                flash.economy.mean_time / flash.static_placement.mean_time
            } else {
                1.0
            },
            "",
        );
        report_metric("bytes moved at flash crowd", flash.economy.bytes_moved, "B");
    }

    let m = Metrics::new();
    m.counter("economy.points").add(report.points.len() as u64);
    m.counter("economy.requests_per_arm").add(n_requests as u64);
    m.histogram("economy.sweep_wall_ns").observe(wall);
    for p in &report.points {
        m.counter("economy.replicas_created").add(p.economy.replicas_created as u64);
        m.counter("economy.evictions").add(p.economy.evictions as u64);
        m.counter("economy.failed_pushes").add(p.economy.failed_pushes as u64);
        m.counter("economy.bytes_moved").add(p.economy.bytes_moved as u64);
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("economy".to_string()));
        root.insert("requests_per_arm".to_string(), Json::Num(n_requests as f64));
        root.insert(
            "points".to_string(),
            Json::Arr(
                report
                    .points
                    .iter()
                    .map(|p| {
                        let mut o = BTreeMap::new();
                        o.insert("scenario".to_string(), Json::Str(p.label.clone()));
                        o.insert("static".to_string(), arm_json(&p.static_placement));
                        o.insert("economy".to_string(), arm_json(&p.economy));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "metrics".to_string(),
            Json::parse(&m.to_json()).expect("snapshot JSON parses"),
        );
        let body = Json::Obj(root).to_string();
        match std::fs::write(&path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
