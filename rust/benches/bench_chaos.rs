//! Chaos sweep bench (ISSUE 7): fault intensity × recovery policy on
//! identically seeded grids — the robustness headline. Each weather
//! point replays the same request trace three times (fail-fast, pinned
//! retry, retry+failover); the completion-rate gap between the first
//! and last arm is the number the PR exists to move.
//!
//! With `BENCH_JSON=<path>` set, every point's per-arm headline numbers
//! (completion rate, mean time-to-recover, p95, goodput, retry/failover
//! counters) are written as JSON — `scripts/bench.sh` uses this to
//! record `BENCH_chaos.json` next to the other perf artifacts.

use std::collections::BTreeMap;
use std::time::Instant;

use globus_replica::config::GridConfig;
use globus_replica::experiment::{run_chaos, ChaosArm, ChaosOptions, RetryOptions};
use globus_replica::metrics::Metrics;
use globus_replica::simnet::{WeatherSpec, WorkloadSpec};
use globus_replica::util::bench::report_metric;
use globus_replica::util::json::Json;

fn arm_json(a: &ChaosArm) -> Json {
    let mut o = BTreeMap::new();
    o.insert("completion_rate".to_string(), Json::Num(a.completion_rate));
    o.insert("mttr_s".to_string(), Json::Num(a.mttr));
    o.insert("p95_time_s".to_string(), Json::Num(a.p95));
    o.insert("goodput_bps".to_string(), Json::Num(a.goodput));
    o.insert("retries".to_string(), Json::Num(a.retries as f64));
    o.insert("failovers".to_string(), Json::Num(a.failovers as f64));
    o.insert("gave_up".to_string(), Json::Num(a.gave_up as f64));
    o.insert("skipped".to_string(), Json::Num(a.skipped as f64));
    Json::Obj(o)
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let cfg = GridConfig::generate(10, 777);
    let spec = WorkloadSpec { files: 12, mean_interarrival: 12.0, ..Default::default() };
    let n_requests = if quick { 10 } else { 30 };

    // Fault intensity ladder: calm (no weather — the parity floor),
    // breeze (rare healing crashes), storm (frequent crashes, some
    // permanent, flapping links), hurricane (most of the grid down at
    // some point; permanent deaths common).
    let weathers: Vec<(&str, WeatherSpec)> = vec![
        ("calm", WeatherSpec::default()),
        (
            "breeze",
            WeatherSpec {
                horizon: 1200.0,
                mtbf: 600.0,
                mttr: 60.0,
                ..WeatherSpec::default()
            },
        ),
        (
            "storm",
            WeatherSpec {
                horizon: 1200.0,
                mtbf: 180.0,
                mttr: 90.0,
                perm_frac: 0.2,
                flap_rate: 1.0 / 300.0,
                flap_duration: 45.0,
                flap_floor: 0.1,
                ..WeatherSpec::default()
            },
        ),
        (
            "hurricane",
            WeatherSpec {
                horizon: 1200.0,
                mtbf: 80.0,
                mttr: 120.0,
                perm_frac: 0.4,
                flap_rate: 1.0 / 150.0,
                flap_duration: 60.0,
                flap_floor: 0.05,
                ..WeatherSpec::default()
            },
        ),
    ];

    let opts = ChaosOptions {
        retry: RetryOptions { transfer_timeout: 30.0, ..RetryOptions::default() },
        ..ChaosOptions::default()
    };

    println!("== chaos: weather sweep (10 sites, {n_requests} requests/arm, 3 arms/point) ==");
    let t0 = Instant::now();
    let report = run_chaos(&cfg, &spec, n_requests, 4, 4, &weathers, &opts);
    let wall = t0.elapsed();

    println!(
        "{:<11} {:>7} {:>7} | {:>9} {:>9} {:>9} | {:>8} {:>8} {:>8}",
        "weather", "crashes", "faults", "ff done", "rt done", "fo done", "fo mttr", "fo p95", "gave up"
    );
    for p in &report.points {
        println!(
            "{:<11} {:>7} {:>7} | {:>8.0}% {:>8.0}% {:>8.0}% | {:>7.1}s {:>7.1}s {:>8}",
            p.label,
            p.crashes,
            p.faults,
            p.fail_fast.completion_rate * 100.0,
            p.retry.completion_rate * 100.0,
            p.retry_failover.completion_rate * 100.0,
            p.retry_failover.mttr,
            p.retry_failover.p95,
            p.fail_fast.gave_up,
        );
    }
    report_metric("sweep wall time", wall.as_secs_f64(), "s");
    if let Some(worst) = report.points.last() {
        report_metric(
            "failover-over-fail-fast completion gain at worst weather",
            worst.retry_failover.completion_rate - worst.fail_fast.completion_rate,
            "",
        );
        report_metric(
            "mean time-to-recover at worst weather",
            worst.retry_failover.mttr,
            "s",
        );
    }

    let m = Metrics::new();
    m.counter("chaos.points").add(report.points.len() as u64);
    m.counter("chaos.requests_per_arm").add(n_requests as u64);
    m.histogram("chaos.sweep_wall_ns").observe(wall);
    for p in &report.points {
        m.counter("chaos.crashes").add(p.crashes as u64);
        m.counter("chaos.retries").add(p.retry_failover.retries as u64);
        m.counter("chaos.failovers").add(p.retry_failover.failovers as u64);
        m.counter("chaos.gave_up_fail_fast").add(p.fail_fast.gave_up as u64);
        m.counter("chaos.gave_up_failover").add(p.retry_failover.gave_up as u64);
    }

    if let Ok(path) = std::env::var("BENCH_JSON") {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("chaos".to_string()));
        root.insert("requests_per_arm".to_string(), Json::Num(n_requests as f64));
        root.insert(
            "points".to_string(),
            Json::Arr(
                report
                    .points
                    .iter()
                    .map(|p| {
                        let mut o = BTreeMap::new();
                        o.insert("weather".to_string(), Json::Str(p.label.clone()));
                        o.insert("crashes".to_string(), Json::Num(p.crashes as f64));
                        o.insert("faults".to_string(), Json::Num(p.faults as f64));
                        o.insert("fail_fast".to_string(), arm_json(&p.fail_fast));
                        o.insert("retry".to_string(), arm_json(&p.retry));
                        o.insert(
                            "retry_failover".to_string(),
                            arm_json(&p.retry_failover),
                        );
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "metrics".to_string(),
            Json::parse(&m.to_json()).expect("snapshot JSON parses"),
        );
        let body = Json::Obj(root).to_string();
        match std::fs::write(&path, &body) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }
}
