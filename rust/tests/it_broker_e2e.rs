//! Integration: the full stack end to end (DESIGN.md R1) — catalog +
//! GRIS providers + GridFTP instrumentation + broker over a simulated
//! grid, plus the decentralized-vs-centralized comparison (§5.1.1).

use std::time::Duration;

use globus_replica::broker::centralized::{
    queueing_latencies_central, queueing_latencies_decentralized, run_centralized,
    run_decentralized, CentralManager,
};
use globus_replica::broker::selectors::SelectorKind;
use globus_replica::broker::RankPolicy;
use globus_replica::classad::parse_classad;
use globus_replica::config::GridConfig;
use globus_replica::experiment::{run_quality, SimGrid};
use globus_replica::simnet::WorkloadSpec;

fn grid_fixture(seed: u64) -> SimGrid {
    let cfg = GridConfig::generate(6, seed);
    let spec = WorkloadSpec { files: 8, ..Default::default() };
    let mut g = SimGrid::build(&cfg, &spec, 3, 32);
    g.warm(6);
    g
}

#[test]
fn full_pipeline_select_and_fetch() {
    let mut g = grid_fixture(501);
    let broker = g.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad(
        "hostname = \"client\"; reqdSpace = 0; requirement = other.AvgRDBandwidth > 0;",
    )
    .unwrap();
    let logical = g.files[0].clone();
    let sel = broker.select(&logical, &request).expect("selection");
    // The winner must actually hold a replica.
    let cat = g.catalog.lock().unwrap();
    let sites: Vec<String> = cat
        .locate(&logical)
        .unwrap()
        .iter()
        .map(|l| l.site.clone())
        .collect();
    drop(cat);
    assert!(sites.contains(&sel.site));
    // Access phase: fetch from the winner, history grows.
    let idx = g.topo.index_of(&sel.site).unwrap();
    let before = g.ftp.history(idx).read().unwrap().rd.count;
    let out = g.ftp.fetch(&mut g.topo, idx, "client", g.sizes[0]);
    assert!(out.duration > 0.0);
    assert_eq!(g.ftp.history(idx).read().unwrap().rd.count, before + 1);
}

#[test]
fn selection_feeds_back_into_next_selection() {
    // After transfers, the GRIS publishes fresh history; selections see
    // rdHistory windows that include the new transfers.
    let mut g = grid_fixture(502);
    let broker = g.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad("requirement = TRUE;").unwrap();
    let logical = g.files[1].clone();
    let (cands0, _) = broker.search(&logical, &request).unwrap();
    let len0: usize = cands0.iter().map(|c| c.history.len()).sum();
    // Fetch from every replica site a few times.
    for _ in 0..3 {
        for c in &cands0 {
            let idx = g.topo.index_of(&c.site).unwrap();
            g.ftp.fetch(&mut g.topo, idx, "client", 4e6);
            g.topo.advance(30.0);
        }
    }
    g.publish_dynamics();
    let (cands1, _) = broker.search(&logical, &request).unwrap();
    let len1: usize = cands1.iter().map(|c| c.history.len()).sum();
    assert!(len1 > len0, "history must grow: {len0} -> {len1}");
}

#[test]
fn quality_ordering_matches_paper_claims() {
    // R7 shape check at test scale: forecast ≥ static ≥ random in
    // optimal-pick rate; forecast strictly beats random in mean time.
    let cfg = GridConfig::generate(8, 903);
    let spec = WorkloadSpec { files: 12, mean_interarrival: 90.0, ..Default::default() };
    let random = run_quality(&cfg, &spec, 80, 3, 8, SelectorKind::Random, None);
    let forecast = run_quality(&cfg, &spec, 80, 3, 8, SelectorKind::Forecast, None);
    assert!(
        forecast.mean_time < random.mean_time,
        "forecast {:.1}s vs random {:.1}s",
        forecast.mean_time,
        random.mean_time
    );
    assert!(forecast.pct_optimal >= random.pct_optimal);
    assert!(forecast.mean_slowdown < random.mean_slowdown);
}

#[test]
fn decentralized_scales_flatter_than_centralized() {
    // §5.1.1: the central manager serializes decisions; per-client
    // brokers do not. The *service cost* is measured from the real
    // broker; the concurrency comparison runs in virtual time (this CI
    // box has 1 core, so wall-clock threads cannot expose parallelism).
    let g = grid_fixture(503);
    let broker = g.broker(RankPolicy::ClassAdRank);
    let request = parse_classad(
        "reqdSpace = 0; rank = other.availableSpace; requirement = TRUE;",
    )
    .unwrap();
    let logical = g.files[0].clone();

    // Measure the real decision service time.
    let t0 = std::time::Instant::now();
    let iters = 20;
    for _ in 0..iters {
        broker.select(&logical, &request).expect("selection");
    }
    let service_s = t0.elapsed().as_secs_f64() / iters as f64;
    assert!(service_s > 0.0);

    // 32 clients, each issuing one request in the same decision window.
    let n = 32;
    let arrivals = vec![0.0; n];
    let client_of: Vec<usize> = (0..n).collect();
    let central = queueing_latencies_central(&arrivals, service_s);
    let decentral = queueing_latencies_decentralized(&arrivals, service_s, &client_of, n);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&central) > mean(&decentral) * 4.0,
        "central {:.2e}s !>> decentralized {:.2e}s",
        mean(&central),
        mean(&decentral)
    );
    // Decentralized latency is flat: last client pays the same as the
    // first; central latency grows with queue position.
    assert!((decentral[n - 1] - decentral[0]).abs() < 1e-12);
    assert!(central[n - 1] > central[0] * (n as f64 / 2.0));

    // The threaded implementations still exist for multicore boxes —
    // smoke them at trivial concurrency.
    let mgr = CentralManager::new(broker.clone(), Duration::from_micros(50));
    let c = run_centralized(&mgr, &logical, &request, 2, 2);
    let d = run_decentralized(&broker, &logical, &request, 2, 2, Duration::from_micros(50));
    assert!(c > Duration::ZERO && d > Duration::ZERO);
}

#[test]
fn constrained_requests_respect_bandwidth_floor() {
    let g = grid_fixture(504);
    let broker = g.broker(RankPolicy::ClassAdRank);
    // A floor that only some sites meet.
    let bws: Vec<f64> = (0..g.topo.len())
        .map(|i| g.ftp.history(i).read().unwrap().rd.avg())
        .collect();
    let mut sorted = bws.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let floor = sorted[sorted.len() / 2]; // median
    let request = parse_classad(&format!(
        "reqdSpace = 0; rank = other.AvgRDBandwidth; \
         requirement = other.AvgRDBandwidth > {floor};"
    ))
    .unwrap();
    for f in 0..g.files.len() {
        if let Ok(sel) = broker.select(&g.files[f], &request) {
            let idx = g.topo.index_of(&sel.site).unwrap();
            assert!(
                bws[idx] > floor,
                "selected site {} violates the floor",
                sel.site
            );
        }
    }
}

#[test]
fn published_predictions_reach_the_broker() {
    // §7 loop: the NWS-style feed publishes predictedRDBandwidth into
    // the GRIS; a plain directory query (no broker-side forecasting)
    // sees it and can rank on it.
    let g = grid_fixture(505);
    let broker = g.broker(RankPolicy::ClassAdRank);
    let request = parse_classad(
        "reqdSpace = 0; rank = other.predictedRDBandwidth; \
         requirement = other.predictedRDBandwidth > 0;",
    )
    .unwrap();
    let sel = broker.select(&g.files[0], &request).expect("selection");
    assert!(sel.score > 0.0, "rank must come from the published prediction");
    for c in &sel.candidates {
        assert!(
            c.ad.number("predictedRDBandwidth").unwrap_or(0.0) > 0.0,
            "site {} did not publish a prediction",
            c.site
        );
        assert!(c.ad.contains("predictor"));
    }
    // The winner publishes the max prediction among candidates.
    let max = sel
        .candidates
        .iter()
        .map(|c| c.ad.number("predictedRDBandwidth").unwrap())
        .fold(f64::NEG_INFINITY, f64::max);
    assert_eq!(sel.score, max);
}
