//! Replica-economy integration tests (ISSUE 10 acceptance).
//!
//! (a) **Flash crowd**: on a grid whose fastest site starts without the
//!     hot file, the economy replicates it there through real kernel
//!     store flows and strictly beats frozen placement on both
//!     hit-rate-at-nearest-replica and mean request time.
//! (b) **Parity**: `economy: None` (and an economy whose tick never
//!     fires) leaves the open-loop run byte-identical to the plain
//!     driver — the engine is pay-for-what-you-use.
//! (c) **Eviction**: a zero space budget drains every duplicate copy,
//!     but the last-copy guard keeps each file servable — the run still
//!     completes everything.
//! (d) **Determinism**: two identically seeded economy runs export
//!     byte-identical traces, replication traffic included.

use globus_replica::broker::replication::PlacementPolicy;
use globus_replica::broker::selectors::SelectorKind;
use globus_replica::broker::EconomyOptions;
use globus_replica::config::GridConfig;
use globus_replica::experiment::{
    run_economy_point, run_quality_open, EconomySweepOptions, OpenLoopOptions, SimGrid,
};
use globus_replica::experiment::economy::{flash_crowd, nearest_site};
use globus_replica::simnet::{Workload, WorkloadSpec};
use globus_replica::trace::TraceHandle;

/// Deterministic single-rate links: durations depend only on sharing.
fn flat_cfg(n: usize, seed: u64) -> GridConfig {
    let mut cfg = GridConfig::generate(n, seed);
    for s in &mut cfg.sites {
        s.wan_bandwidth = 1e6;
        s.diurnal_amp = 0.0;
        s.noise_frac = 0.0;
        s.congestion_prob = 0.0;
        s.ar_coeff = 0.0;
        s.latency = 0.0;
        s.drd_time_ms = 0.0;
        s.disk_rate = 1e9;
    }
    cfg
}

/// The acceptance anchor: under a flash crowd on identically seeded
/// grids, the economy strictly beats static placement on
/// hit-rate-at-nearest-replica *and* mean time, and pays for it in
/// `bytes_moved`.
#[test]
fn flash_crowd_economy_beats_static_placement() {
    let spec = WorkloadSpec { files: 5, mean_interarrival: 10.0, ..Default::default() };
    let mut cfg = flat_cfg(5, 4242);
    // Find the hot file's seed home on the value-flattened grid, then
    // make a *different* site overwhelmingly fastest and biggest. The
    // seed shuffle depends only on (seed, counts), not on site values —
    // the probe below pins that assumption.
    let home = SimGrid::build(&cfg, &spec, 1, 16).placement[0][0];
    let fast = (home + 1) % cfg.sites.len();
    cfg.sites[fast].wan_bandwidth = 1e8;
    cfg.sites[fast].total_space = 1e12;
    cfg.sites[fast].used_frac = 0.0;
    let probe = SimGrid::build(&cfg, &spec, 1, 16);
    assert_eq!(probe.placement[0], vec![home], "seed placement must ignore site values");
    assert_eq!(nearest_site(&cfg, probe.sizes[0]), fast);

    let requests = flash_crowd(&spec, cfg.seed, 40);
    let opts = EconomySweepOptions {
        kind: SelectorKind::Forecast,
        open: OpenLoopOptions::open(),
        economy: EconomyOptions {
            period: 15.0,
            half_life: 60.0,
            replicate_threshold: 2.5,
            max_replicas_per_file: 3,
            budget_frac: 0.9,
            evict_threshold: 0.0,
            max_actions_per_tick: 2,
            placement: PlacementPolicy::MostSpace,
        },
    };
    let p = run_economy_point(&cfg, &spec, &requests, 1, 4, &opts, "flash");

    assert!(
        p.economy.replicas_created > 0,
        "the crowd must trigger replication: {:?}",
        p.economy.report.economy
    );
    assert!(p.economy.bytes_moved > 0.0);
    assert!(
        p.economy.hit_rate_nearest > p.static_placement.hit_rate_nearest,
        "economy hit-rate {:.2} must beat static {:.2}",
        p.economy.hit_rate_nearest,
        p.static_placement.hit_rate_nearest
    );
    assert!(
        p.economy.mean_time < p.static_placement.mean_time,
        "economy mean {:.1}s must beat static {:.1}s",
        p.economy.mean_time,
        p.static_placement.mean_time
    );
}

/// The parity anchor: `economy: None` exports a byte-identical trace to
/// the plain open-loop run, and so does an economy whose tick never
/// fires (`period: ∞`) — arrival bookkeeping alone must not perturb the
/// kernel schedule.
#[test]
fn economy_off_is_bit_identical_to_plain_open_loop() {
    let cfg = GridConfig::generate(5, 99);
    let spec = WorkloadSpec { files: 6, mean_interarrival: 20.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(15);
    let export = |economy: Option<EconomyOptions>| {
        let trace = TraceHandle::new(1 << 14);
        let o = OpenLoopOptions {
            trace: trace.clone(),
            sample_period: 40.0,
            economy,
            ..OpenLoopOptions::open()
        };
        run_quality_open(&cfg, &spec, &reqs, 2, 3, SelectorKind::Forecast, &o, None);
        let mut out = String::new();
        trace.with(|r| out = r.jsonl());
        out
    };
    let plain = export(None);
    let idle = export(Some(EconomyOptions { period: f64::INFINITY, ..EconomyOptions::default() }));
    assert!(!plain.is_empty());
    assert_eq!(plain, idle, "an idle economy must not perturb the schedule");
}

/// A zero budget drains every duplicate replica, but the last-copy
/// guard keeps the catalog servable: every request still completes.
#[test]
fn zero_budget_evicts_duplicates_but_never_strands_a_file() {
    let cfg = flat_cfg(4, 777);
    let spec = WorkloadSpec { files: 4, mean_interarrival: 15.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(20);
    let o = OpenLoopOptions {
        economy: Some(EconomyOptions {
            period: 10.0,
            budget_frac: 0.0,
            // No replication: isolate the eviction path.
            replicate_threshold: f64::INFINITY,
            evict_threshold: f64::INFINITY,
            max_actions_per_tick: 4,
            ..EconomyOptions::default()
        }),
        ..OpenLoopOptions::open()
    };
    let r = run_quality_open(&cfg, &spec, &reqs, 2, 3, SelectorKind::Forecast, &o, None);
    let stats = r.economy.expect("economy stats present when on");
    assert!(stats.evictions > 0, "a zero budget must evict duplicates: {stats:?}");
    assert_eq!(stats.replicas_created, 0);
    assert_eq!(r.skipped, 0, "no request may be stranded by eviction");
    assert_eq!(r.per_request.len(), 20, "every request completes off the last copies");
}

/// Two identically seeded economy runs export byte-identical traces,
/// and the replication traffic actually shows up in them.
#[test]
fn identically_seeded_economy_runs_export_identical_traces() {
    let spec = WorkloadSpec { files: 5, mean_interarrival: 8.0, ..Default::default() };
    let mut cfg = flat_cfg(5, 4242);
    let home = SimGrid::build(&cfg, &spec, 1, 16).placement[0][0];
    let fast = (home + 1) % cfg.sites.len();
    cfg.sites[fast].wan_bandwidth = 1e8;
    cfg.sites[fast].total_space = 1e12;
    cfg.sites[fast].used_frac = 0.0;
    let reqs = flash_crowd(&spec, cfg.seed, 30);
    let export = || {
        let trace = TraceHandle::new(1 << 15);
        let o = OpenLoopOptions {
            trace: trace.clone(),
            sample_period: 30.0,
            economy: Some(EconomyOptions {
                period: 12.0,
                half_life: 60.0,
                replicate_threshold: 2.0,
                ..EconomyOptions::default()
            }),
            ..OpenLoopOptions::open()
        };
        run_quality_open(&cfg, &spec, &reqs, 1, 4, SelectorKind::Forecast, &o, None);
        let mut out = String::new();
        trace.with(|r| out = r.jsonl());
        out
    };
    let a = export();
    let b = export();
    assert!(!a.is_empty());
    assert_eq!(a, b, "economy trace export must be byte-identical across runs");
    assert!(a.contains("replica_push"), "replication traffic must appear in the trace");
    assert!(a.contains("replica_create"), "committed replicas must appear in the trace");
}
