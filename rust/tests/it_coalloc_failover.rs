//! Integration: co-allocated transfers under churn — a replica dying
//! mid-transfer must not fail the transfer (ISSUE 3 acceptance).
//!
//! End to end: broker top-K selection → stripe plan → scheduler, with
//! `simnet` killing the plan's predicted-best source partway through.
//! Asserts the transfer completes, the assembled byte ranges cover the
//! file exactly once, retries stay within the policy bound, and the
//! failover counters surface through `metrics::Metrics`.

use globus_replica::broker::RankPolicy;
use globus_replica::classad::parse_classad;
use globus_replica::coalloc;
use globus_replica::config::{CoallocPolicy, GridConfig, SiteConfig};
use globus_replica::experiment::SimGrid;
use globus_replica::metrics::Metrics;
use globus_replica::simnet::{FaultKind, WorkloadSpec};

/// Four similar, steady sites: every plan stripes over all of them, so
/// killing one leaves three survivors to absorb its blocks.
fn steady_grid() -> GridConfig {
    let site = |name: &str, wan: f64| SiteConfig {
        name: name.to_string(),
        org: "grid".to_string(),
        disk_rate: 1e8,
        total_space: 100.0 * 1024f64.powi(3),
        used_frac: 0.3,
        wan_bandwidth: wan,
        diurnal_amp: 0.05,
        ar_coeff: 0.4,
        noise_frac: 0.02,
        congestion_prob: 0.0,
        latency: 0.02,
        drd_time_ms: 5.0,
        dwr_time_ms: 6.0,
    };
    GridConfig {
        sites: vec![
            site("alpha", 1.6e6),
            site("beta", 1.4e6),
            site("gamma", 1.2e6),
            site("delta", 1.0e6),
        ],
        seed: 20260730,
    }
}

#[test]
fn it_coalloc_failover() {
    let cfg = steady_grid();
    let spec = WorkloadSpec { files: 2, ..Default::default() };
    let mut g = SimGrid::build(&cfg, &spec, 4, 32);
    g.warm(6);

    let broker = g.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad(
        "hostname = \"client\"; reqdSpace = 0; requirement = other.AvgRDBandwidth > 0;",
    )
    .unwrap();
    let logical = g.files[0].clone();
    let size = 600e6; // ~37 blocks at 16 MiB
    let policy = CoallocPolicy {
        max_streams: 4,
        tick: 2.0,
        max_block_retries: 3,
        ..Default::default()
    };

    let sel = broker
        .select_coalloc(&logical, &request, size, &policy)
        .expect("coalloc selection");
    assert_eq!(sel.plan.assignments.len(), 4, "all four replicas stripe");

    // Kill the plan's largest stripe — the predicted-best source —
    // roughly a third of the way into the predicted makespan.
    let victim = sel
        .plan
        .assignments
        .iter()
        .max_by(|a, b| a.share.partial_cmp(&b.share).unwrap())
        .unwrap()
        .source
        .site
        .clone();
    let victim_idx = g.topo.index_of(&victim).unwrap();
    let planned_victim_blocks = sel
        .plan
        .assignments
        .iter()
        .find(|a| a.source.site == victim)
        .unwrap()
        .blocks;
    let death_at = g.topo.now + sel.plan.predicted_makespan() / 3.0;
    g.topo.schedule_fault(victim_idx, death_at, FaultKind::ReplicaDeath);

    let before_counts: Vec<u64> = (0..g.topo.len())
        .map(|i| g.ftp.history(i).read().unwrap().rd.count)
        .collect();

    // The acceptance claim: the death does NOT fail the transfer.
    let out = coalloc::execute(&mut g.topo, &g.ftp, "client", &sel.plan, &policy)
        .expect("transfer must survive the replica death");

    // Every byte range was delivered exactly once: the scheduler's
    // internal ledger enforced per-block uniqueness (a duplicate is an
    // execute() error), and the totals confirm full coverage.
    assert!((out.bytes - size).abs() < 1.0, "bytes {} != {size}", out.bytes);
    let delivered: usize = out.streams.iter().map(|s| s.blocks).sum();
    assert_eq!(delivered, sel.plan.n_blocks, "every block exactly once");

    // The failover actually happened and was absorbed by survivors.
    assert_eq!(out.failovers, 1);
    assert!(out.blocks_requeued > 0);
    let dead = out.streams.iter().find(|s| s.site == victim).unwrap();
    assert!(dead.failed);
    assert!(
        dead.blocks < planned_victim_blocks,
        "the dead stream cannot have delivered its whole stripe"
    );
    let survivor_blocks: usize = out
        .streams
        .iter()
        .filter(|s| s.site != victim)
        .map(|s| s.blocks)
        .sum();
    assert_eq!(dead.blocks + survivor_blocks, sel.plan.n_blocks);

    // Retries stayed within the policy bound.
    assert!(
        out.retries_peak <= policy.max_block_retries,
        "retries {} exceed bound {}",
        out.retries_peak,
        policy.max_block_retries
    );

    // Failure counters appear in Metrics.
    let m = Metrics::new();
    out.record_metrics(&m);
    assert_eq!(m.counter("coalloc.failovers").get(), 1);
    assert!(m.counter("coalloc.blocks_requeued").get() > 0);
    assert!(m.counter(&format!("coalloc.failures.{victim}")).get() >= 1);
    assert_eq!(m.counter("coalloc.transfers").get(), 1);
    let rendered = m.render();
    assert!(rendered.contains("coalloc.failovers"));

    // Instrumentation: delivered blocks (and only those) landed in the
    // same history stores the GRIS providers read.
    for s in &out.streams {
        let h = g.ftp.history(s.site_index);
        let h = h.read().unwrap();
        assert_eq!(
            h.rd.count,
            before_counts[s.site_index] + s.blocks as u64,
            "history count mismatch at {}",
            s.site
        );
    }

    // Transfer-slot accounting balanced through the failover.
    for i in 0..g.topo.len() {
        assert_eq!(g.topo.site(i).active_transfers, 0);
    }
}

#[test]
fn it_coalloc_crash_then_recover_revives_the_stream() {
    // ISSUE 7 grid weather: the predicted-best source crashes a third
    // of the way in and RECOVERS while work remains. The failover
    // machinery orphans its queue as before, but the healed stream
    // must rejoin the session (not sit out the rest of the transfer):
    // it ends in the finished state, not failed, and every block still
    // lands exactly once.
    let cfg = steady_grid();
    let spec = WorkloadSpec { files: 2, ..Default::default() };
    let mut g = SimGrid::build(&cfg, &spec, 4, 32);
    g.warm(6);
    let broker = g.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad("requirement = TRUE;").unwrap();
    let logical = g.files[0].clone();
    let size = 600e6;
    let policy = CoallocPolicy {
        max_streams: 4,
        tick: 2.0,
        max_block_retries: 3,
        ..Default::default()
    };
    let sel = broker
        .select_coalloc(&logical, &request, size, &policy)
        .expect("coalloc selection");
    let victim = sel
        .plan
        .assignments
        .iter()
        .max_by(|a, b| a.share.partial_cmp(&b.share).unwrap())
        .unwrap()
        .source
        .site
        .clone();
    let victim_idx = g.topo.index_of(&victim).unwrap();
    let makespan = sel.plan.predicted_makespan();
    // Down for a third of the predicted makespan, healing with plenty
    // of the transfer left for the revived stream to work on.
    g.topo.schedule_fault_for(
        victim_idx,
        g.topo.now + makespan / 3.0,
        makespan / 3.0,
        FaultKind::ReplicaDeath,
    );
    let out = coalloc::execute(&mut g.topo, &g.ftp, "client", &sel.plan, &policy)
        .expect("transfer must survive a crash the source recovers from");
    assert!((out.bytes - size).abs() < 1.0);
    let delivered: usize = out.streams.iter().map(|s| s.blocks).sum();
    assert_eq!(delivered, sel.plan.n_blocks, "every block exactly once");
    assert_eq!(out.failovers, 1, "the crash registered as a failover");
    let revived = out.streams.iter().find(|s| s.site == victim).unwrap();
    assert!(
        !revived.failed,
        "a healed source must rejoin the session, not end failed"
    );
    assert_eq!(revived.failures, 1);
    for i in 0..g.topo.len() {
        assert_eq!(g.topo.site(i).active_transfers, 0);
    }
}

#[test]
fn failover_disabled_reproduces_the_fragile_baseline() {
    // Same scenario, failover off: the death kills the transfer — the
    // behaviour the churn experiment scores single-best/striped by.
    let cfg = steady_grid();
    let spec = WorkloadSpec { files: 2, ..Default::default() };
    let mut g = SimGrid::build(&cfg, &spec, 4, 32);
    g.warm(6);
    let broker = g.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad("requirement = TRUE;").unwrap();
    let logical = g.files[0].clone();
    let policy = CoallocPolicy {
        max_streams: 4,
        tick: 2.0,
        max_block_retries: 0,
        ..Default::default()
    };
    let sel = broker
        .select_coalloc(&logical, &request, 600e6, &policy)
        .expect("selection");
    let victim = &sel.plan.assignments[0].source.site;
    let victim_idx = g.topo.index_of(victim).unwrap();
    g.topo.schedule_fault(
        victim_idx,
        g.topo.now + sel.plan.predicted_makespan() / 3.0,
        FaultKind::ReplicaDeath,
    );
    let err = coalloc::execute(&mut g.topo, &g.ftp, "client", &sel.plan, &policy)
        .expect_err("no-failover transfer must abort on the death");
    assert!(format!("{err:#}").contains("failover is disabled"));
    for i in 0..g.topo.len() {
        assert_eq!(g.topo.site(i).active_transfers, 0);
    }
}
