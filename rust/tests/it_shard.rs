//! Sharded-broker integration suite (ISSUE 8 acceptance).
//!
//! (a) **Parity**: the sharded driver at 1 shard with batch size 1
//!     ([`ShardOptions::parity`]) reproduces the unsharded
//!     `run_quality_open` **bit-for-bit** — every report field,
//!     including the per-request traces — on the plain, gated and
//!     discovery-mode configurations. Same discipline as the PR 4–7
//!     parity anchors: scaling machinery must collapse exactly onto
//!     the path it generalizes.
//! (b) **Determinism**: an N-shard run is a pure function of its
//!     seed — two identical invocations agree on everything,
//!     per-shard telemetry included.
//! (c) **Conservation**: per shard,
//!     `finished + skipped + gave_up == arrivals` exactly, whatever
//!     the batch size or window — admission batching may delay or
//!     wind-down a request but can never lose or double-count one.

use globus_replica::broker::selectors::SelectorKind;
use globus_replica::config::GridConfig;
use globus_replica::experiment::{
    run_quality_open, run_quality_sharded, DiscoveryOptions, OpenLoopOptions, ShardOptions,
};
use globus_replica::simnet::{Workload, WorkloadSpec};

/// Bitwise f64 equality via `Debug` round-tripping: Rust's `{:?}` for
/// floats prints the shortest string that parses back to the same
/// bits, so equal Debug strings ⇔ equal bits, recursively across the
/// whole report.
fn assert_bitwise_eq<T: std::fmt::Debug>(a: &T, b: &T, what: &str) {
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "{what} diverged");
}

#[test]
fn one_shard_parity_is_bitwise() {
    let cfg = GridConfig::generate(6, 8101);
    let spec = WorkloadSpec { files: 8, mean_interarrival: 8.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(25);
    for kind in [SelectorKind::Forecast, SelectorKind::Random] {
        let opts = OpenLoopOptions::open();
        let plain = run_quality_open(&cfg, &spec, &reqs, 3, 2, kind, &opts, None);
        let sharded = run_quality_sharded(
            &cfg,
            &spec,
            &reqs,
            3,
            2,
            kind,
            &opts,
            &ShardOptions::parity(),
            None,
        );
        assert_bitwise_eq(&plain, &sharded.open, "1-shard open report");
        assert_eq!(sharded.shards.len(), 1);
        assert_eq!(sharded.cross_shard_selections, 0, "one shard cannot span");
        let s = &sharded.shards[0];
        assert_eq!(s.arrivals, reqs.len());
        assert_eq!(s.finished + s.skipped + s.gave_up, s.arrivals);
    }
}

#[test]
fn one_shard_parity_holds_under_gate() {
    let cfg = GridConfig::generate(5, 8102);
    let spec = WorkloadSpec { files: 6, mean_interarrival: 4.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(18);
    let opts = OpenLoopOptions { max_in_flight: 2, ..OpenLoopOptions::open() };
    let plain = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
    let sharded = run_quality_sharded(
        &cfg,
        &spec,
        &reqs,
        3,
        2,
        SelectorKind::Forecast,
        &opts,
        &ShardOptions::parity(),
        None,
    );
    assert_bitwise_eq(&plain, &sharded.open, "gated 1-shard report");
    assert!(plain.peak_in_flight <= 2);
}

#[test]
fn one_shard_parity_holds_under_discovery() {
    let cfg = GridConfig::generate(6, 8103);
    let spec = WorkloadSpec { files: 6, mean_interarrival: 20.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(12);
    let opts = OpenLoopOptions {
        discovery: Some(DiscoveryOptions { drill_down: 2, ..Default::default() }),
        ..OpenLoopOptions::open()
    };
    let plain = run_quality_open(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, None);
    let sharded = run_quality_sharded(
        &cfg,
        &spec,
        &reqs,
        3,
        2,
        SelectorKind::Forecast,
        &opts,
        &ShardOptions::parity(),
        None,
    );
    assert_bitwise_eq(&plain, &sharded.open, "discovery 1-shard report");
    // The single shard's domain answered everything the shared
    // hierarchy would have: identical query accounting.
    assert_eq!(plain.discovery, sharded.open.discovery);
}

#[test]
fn n_shard_runs_are_deterministic() {
    let cfg = GridConfig::generate(9, 8104);
    let spec = WorkloadSpec { files: 10, mean_interarrival: 6.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(30);
    let opts = OpenLoopOptions {
        discovery: Some(DiscoveryOptions { drill_down: 2, ..Default::default() }),
        ..OpenLoopOptions::open()
    };
    let so = ShardOptions { shards: 3, batch_max: 4, batch_window: 3.0 };
    let run = || {
        run_quality_sharded(&cfg, &spec, &reqs, 3, 2, SelectorKind::Forecast, &opts, &so, None)
    };
    let a = run();
    let b = run();
    assert_bitwise_eq(&a, &b, "repeated N-shard run");
    assert_eq!(a.shards.len(), 3);
}

/// Property: whatever the partition and batching, per-shard admission
/// accounting conserves requests exactly.
#[test]
fn batching_conserves_outcome_accounting() {
    for (seed, shards, batch_max, window) in [
        (9001u64, 2usize, 1usize, 0.0f64),
        (9002, 3, 4, 5.0),
        (9003, 5, 16, 2.0),
        (9004, 4, 8, f64::INFINITY),
        (9005, 2, 64, 10.0),
    ] {
        let cfg = GridConfig::generate(10, seed);
        let spec = WorkloadSpec { files: 9, mean_interarrival: 5.0, ..Default::default() };
        let reqs = Workload::new(spec.clone(), cfg.seed).take(40);
        let so = ShardOptions { shards, batch_max, batch_window: window };
        let r = run_quality_sharded(
            &cfg,
            &spec,
            &reqs,
            3,
            2,
            SelectorKind::Forecast,
            &OpenLoopOptions::open(),
            &so,
            None,
        );
        let mut arrivals = 0;
        for (s, st) in r.shards.iter().enumerate() {
            assert_eq!(
                st.finished + st.skipped + st.gave_up,
                st.arrivals,
                "shard {s} leaks requests (seed {seed}, {shards} shards, batch {batch_max})"
            );
            assert!(st.admitted <= st.arrivals);
            arrivals += st.arrivals;
        }
        assert_eq!(arrivals, reqs.len(), "every arrival routed to exactly one home shard");
        let finished: usize = r.shards.iter().map(|s| s.finished).sum();
        let skipped: usize = r.shards.iter().map(|s| s.skipped).sum();
        let gave_up: usize = r.shards.iter().map(|s| s.gave_up).sum();
        assert_eq!(finished, r.open.quality.requests, "per-shard finished sums to the report");
        assert_eq!(skipped, r.open.skipped, "per-shard skipped sums to the report");
        assert_eq!(gave_up, r.open.gave_up, "per-shard gave_up sums to the report");
        let admitted: usize = r.shards.iter().map(|s| s.admitted).sum();
        assert!(r.cross_shard_selections <= admitted);
    }
}

#[test]
fn fully_replicated_files_make_every_selection_cross_shard() {
    let cfg = GridConfig::generate(6, 8105);
    let spec = WorkloadSpec { files: 5, mean_interarrival: 10.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(15);
    // Every file on every site: with > 1 shard each replica set spans
    // all shards, so every admission is a cross-shard selection.
    let so = ShardOptions { shards: 3, batch_max: 2, batch_window: 4.0 };
    let r = run_quality_sharded(
        &cfg,
        &spec,
        &reqs,
        6,
        2,
        SelectorKind::Forecast,
        &OpenLoopOptions::open(),
        &so,
        None,
    );
    let admitted: usize = r.shards.iter().map(|s| s.admitted).sum();
    assert_eq!(admitted, reqs.len(), "ungated run admits every arrival");
    assert_eq!(r.cross_shard_selections, admitted);
}

#[test]
fn window_timer_flushes_partial_batches() {
    let cfg = GridConfig::generate(5, 8106);
    let spec = WorkloadSpec { files: 6, mean_interarrival: 15.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(10);
    // Batches that can never fill (batch_max ≫ arrivals): only the
    // window timer stands between an arrival and its admission.
    let so = ShardOptions { shards: 2, batch_max: 1000, batch_window: 2.0 };
    let r = run_quality_sharded(
        &cfg,
        &spec,
        &reqs,
        3,
        2,
        SelectorKind::Forecast,
        &OpenLoopOptions::open(),
        &so,
        None,
    );
    assert_eq!(r.open.quality.requests, 10, "skipped {}", r.open.skipped);
    assert_eq!(r.open.skipped, 0);
    let flushes: usize = r.shards.iter().map(|s| s.flushes).sum();
    assert!(flushes >= 2, "window flushes must have fired, got {flushes}");
    // Admission happened at the flush instant, not the arrival instant:
    // the batching delay is visible in the admitted_at timestamps.
    let t0_arrivals: Vec<f64> = reqs.iter().map(|q| q.at).collect();
    let min_arrival = t0_arrivals.iter().cloned().fold(f64::INFINITY, f64::min);
    let min_admitted = r
        .open
        .per_request
        .iter()
        .map(|t| t.admitted_at)
        .fold(f64::INFINITY, f64::min);
    assert!(min_admitted >= min_arrival, "admission cannot precede arrival");
}
