//! Property-based tests over the coordinator's core invariants
//! (routing/matching/state — the L3 contract), using the in-tree
//! `util::prop` runner (seeded, replayable).

use globus_replica::classad::{
    eval_in_match, parse_classad, rank_candidates, symmetric_match, AdBuilder, Value,
};
use globus_replica::directory::entry::{Dn, Entry};
use globus_replica::directory::ldif::{parse_ldif, to_ldif_stream};
use globus_replica::directory::{Dit, Filter, Scope};
use globus_replica::forecast::forecast_bank;
use globus_replica::util::prng::Rng;
use globus_replica::util::prop::{forall, Config};

fn cfg(cases: u64) -> Config {
    Config { cases, ..Config::default() }
}

fn random_ad(rng: &mut Rng) -> globus_replica::classad::ClassAd {
    let mut b = AdBuilder::new();
    let n = 1 + rng.index(6);
    for i in 0..n {
        let name = format!("attr{i}");
        b = match rng.index(5) {
            0 => b.int(&name, rng.below(1_000_000) as i64 - 500_000),
            1 => b.real(&name, rng.range(-1e6, 1e6)),
            2 => b.str(&name, format!("s{}", rng.below(100))),
            3 => b.bool(&name, rng.chance(0.5)),
            _ => b.bytes(&name, rng.range(0.0, 1e12)),
        };
    }
    b.build()
}

#[test]
fn prop_classad_unparse_reparse_fixpoint() {
    forall("classad unparse/reparse", cfg(300), |rng| {
        let ad = random_ad(rng);
        let text = ad.to_string();
        let re = parse_classad(&text).map_err(|e| format!("{e} in {text:?}"))?;
        if re != ad {
            return Err(format!("mismatch:\n{ad}\nvs\n{re}"));
        }
        Ok(())
    });
}

#[test]
fn prop_matchmaking_is_symmetric_and_rank_deterministic() {
    forall("symmetric match + stable rank", cfg(200), |rng| {
        let mut storage = random_ad(rng);
        storage.set_value("availableSpace", Value::Real(rng.range(0.0, 1e12)));
        let request = parse_classad(
            "rank = other.availableSpace; requirement = other.availableSpace >= 0;",
        )
        .unwrap();
        if symmetric_match(&request, &storage) != symmetric_match(&storage, &request) {
            return Err("match not symmetric".into());
        }
        let ads = vec![storage.clone(), storage.clone()];
        let ranked = rank_candidates(&request, &ads);
        if ranked.len() == 2 && ranked[0].index != 0 {
            return Err("equal ranks must preserve catalog order".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rank_ordering_matches_attribute_ordering() {
    forall("rank order == availableSpace order", cfg(150), |rng| {
        let n = 2 + rng.index(8);
        let spaces: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1e9)).collect();
        let ads: Vec<_> = spaces
            .iter()
            .map(|s| AdBuilder::new().real("availableSpace", *s).build())
            .collect();
        let request = parse_classad("rank = other.availableSpace;").unwrap();
        let ranked = rank_candidates(&request, &ads);
        for w in ranked.windows(2) {
            if w[0].rank < w[1].rank {
                return Err(format!("rank order violated: {} < {}", w[0].rank, w[1].rank));
            }
        }
        let best = ranked[0].index;
        let max = spaces
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        if (spaces[best] - max).abs() > 1e-9 {
            return Err("winner is not argmax(availableSpace)".into());
        }
        Ok(())
    });
}

#[test]
fn prop_three_valued_logic_never_panics_and_is_total() {
    // Random expressions over a small grammar evaluate to *some* value.
    forall("eval is total", cfg(300), |rng| {
        let atoms = ["1", "2.5", "\"x\"", "TRUE", "FALSE", "UNDEFINED", "ERROR", "missing", "5G"];
        let ops = ["+", "-", "*", "/", "==", "!=", "<", ">", "&&", "||", "=?="];
        let mut expr = (*rng.choose(&atoms)).to_string();
        for _ in 0..rng.index(6) {
            expr = format!("({expr} {} {})", rng.choose(&ops), rng.choose(&atoms));
        }
        let ad = parse_classad(&format!("x = {expr};")).map_err(|e| format!("{e}: {expr}"))?;
        let _ = ad.value("x"); // must not panic
        Ok(())
    });
}

#[test]
fn prop_ldif_round_trip() {
    forall("ldif round trip", cfg(200), |rng| {
        let n_entries = 1 + rng.index(4);
        let mut entries = Vec::new();
        for i in 0..n_entries {
            let mut e = Entry::new(Dn::parse(&format!("gss=v{i}, o=grid")).unwrap());
            e.add("objectClass", "GridStorageServerVolume");
            for a in 0..rng.index(6) {
                let val = match rng.index(3) {
                    0 => format!("{}", rng.range(-1e9, 1e9)),
                    1 => format!("str-{}", rng.below(1000)),
                    _ => " leading space needs b64".to_string(),
                };
                e.add(&format!("attr{a}"), val);
            }
            entries.push(e);
        }
        let text = to_ldif_stream(&entries);
        let parsed = parse_ldif(&text).map_err(|e| e.to_string())?;
        if parsed != entries {
            return Err("ldif round trip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dit_search_scope_containment() {
    // Sub results ⊇ One results ⊇ nothing outside base.
    forall("dit scope containment", cfg(100), |rng| {
        let mut dit = Dit::new();
        let orgs = ["anl", "lbl", "isi"];
        for org in orgs {
            for s in 0..(1 + rng.index(3)) {
                let dn = Dn::parse(&format!("gss=v{s}, o={org}, o=grid")).unwrap();
                let mut e = Entry::new(dn);
                e.add("objectClass", "GridStorageServerVolume");
                e.put_f64("availableSpace", rng.range(0.0, 100.0));
                dit.add_with_ancestors(e).unwrap();
            }
        }
        let base = Dn::parse(&format!("o={}, o=grid", rng.choose(&orgs))).unwrap();
        let all = Filter::parse("(objectClass=*)").unwrap();
        let sub = dit.search(&base, Scope::Sub, &all);
        let one = dit.search(&base, Scope::One, &all);
        for e in &one {
            if !sub.iter().any(|s| s.dn == e.dn) {
                return Err("One result missing from Sub".into());
            }
        }
        for e in &sub {
            if !e.dn.under(&base) {
                return Err(format!("entry {} escapes base {base}", e.dn));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_forecast_bank_invariants() {
    forall("forecast bank invariants", cfg(200), |rng| {
        let n = rng.index(50);
        let hist: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1e6)).collect();
        let mask: Vec<f64> = (0..n).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect();
        let out = forecast_bank(&hist, &mask);
        let lo = hist
            .iter()
            .zip(&mask)
            .filter(|(_, m)| **m > 0.5)
            .map(|(h, _)| *h)
            .fold(f64::INFINITY, f64::min);
        let hi = hist
            .iter()
            .zip(&mask)
            .filter(|(_, m)| **m > 0.5)
            .map(|(h, _)| *h)
            .fold(f64::NEG_INFINITY, f64::max);
        for (p, v) in out.preds.iter().enumerate() {
            if !v.is_finite() {
                return Err(format!("predictor {p} not finite"));
            }
            if lo.is_finite() && (*v < lo - 1e-6 || *v > hi + 1e-6) {
                return Err(format!(
                    "predictor {p} = {v} outside observed range [{lo}, {hi}]"
                ));
            }
        }
        for (p, m) in out.mses.iter().enumerate() {
            if *m < 0.0 || !m.is_finite() {
                return Err(format!("mse {p} = {m} invalid"));
            }
        }
        if out.mses[out.best_index()] > out.mses.iter().cloned().fold(f64::INFINITY, f64::min) {
            return Err("best_index is not argmin".into());
        }
        Ok(())
    });
}

#[test]
fn prop_match_context_attribute_resolution() {
    // other.X in the request always sees the storage value, regardless
    // of name collisions with the request's own attributes.
    forall("other-scope resolution", cfg(150), |rng| {
        let v_req = rng.range(0.0, 1e6);
        let v_sto = rng.range(0.0, 1e6);
        let request = parse_classad(&format!(
            "availableSpace = {v_req}; probe = other.availableSpace;"
        ))
        .unwrap();
        let storage = parse_classad(&format!("availableSpace = {v_sto};")).unwrap();
        match eval_in_match(&request, &storage, "probe") {
            Value::Real(got) if (got - v_sto).abs() < 1e-9 => Ok(()),
            other => Err(format!("probe = {other:?}, want {v_sto}")),
        }
    });
}
