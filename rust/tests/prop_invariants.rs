//! Property-based tests over the coordinator's core invariants
//! (routing/matching/state — the L3 contract) and the shared
//! concurrent-flow engine (bandwidth conservation + no starvation,
//! ISSUE 4), using the in-tree `util::prop` runner (seeded,
//! replayable).

use globus_replica::classad::{
    ast::{BinOp, Scope as AdScope, UnOp},
    eval_in_match, parse_classad, rank_candidates, symmetric_match, AdBuilder, AttrName,
    CandidateTable, ClassAd, CompiledMatch, Expr, Value, VmScratch,
};
use globus_replica::config::GridConfig;
use globus_replica::directory::entry::{Dn, Entry};
use globus_replica::directory::ldif::{parse_ldif, to_ldif_stream};
use globus_replica::directory::{Dit, Filter, Scope};
use globus_replica::directory::fanout::{run_fanout, DirectoryFanout, FanoutPolicy, QueryIds};
use globus_replica::broker::replication::{PlacementPolicy, ReplicaManager};
use globus_replica::broker::SelectorKind;
use globus_replica::experiment::{run_quality_open, OpenLoopOptions, RetryOptions, SimGrid};
use globus_replica::forecast::forecast_bank;
use globus_replica::simnet::{
    Engine, FaultKind, FlowSet, Signal, Topology, WeatherPlan, WeatherSpec, Workload, WorkloadSpec,
};
use globus_replica::trace::TraceHandle;
use globus_replica::util::prng::Rng;
use globus_replica::util::prop::{forall, Config};

fn cfg(cases: u64) -> Config {
    Config { cases, ..Config::default() }
}

fn random_ad(rng: &mut Rng) -> globus_replica::classad::ClassAd {
    let mut b = AdBuilder::new();
    let n = 1 + rng.index(6);
    for i in 0..n {
        let name = format!("attr{i}");
        b = match rng.index(5) {
            0 => b.int(&name, rng.below(1_000_000) as i64 - 500_000),
            1 => b.real(&name, rng.range(-1e6, 1e6)),
            2 => b.str(&name, format!("s{}", rng.below(100))),
            3 => b.bool(&name, rng.chance(0.5)),
            _ => b.bytes(&name, rng.range(0.0, 1e12)),
        };
    }
    b.build()
}

#[test]
fn prop_classad_unparse_reparse_fixpoint() {
    forall("classad unparse/reparse", cfg(300), |rng| {
        let ad = random_ad(rng);
        let text = ad.to_string();
        let re = parse_classad(&text).map_err(|e| format!("{e} in {text:?}"))?;
        if re != ad {
            return Err(format!("mismatch:\n{ad}\nvs\n{re}"));
        }
        Ok(())
    });
}

#[test]
fn prop_matchmaking_is_symmetric_and_rank_deterministic() {
    forall("symmetric match + stable rank", cfg(200), |rng| {
        let mut storage = random_ad(rng);
        storage.set_value("availableSpace", Value::Real(rng.range(0.0, 1e12)));
        let request = parse_classad(
            "rank = other.availableSpace; requirement = other.availableSpace >= 0;",
        )
        .unwrap();
        if symmetric_match(&request, &storage) != symmetric_match(&storage, &request) {
            return Err("match not symmetric".into());
        }
        let ads = vec![storage.clone(), storage.clone()];
        let ranked = rank_candidates(&request, &ads);
        if ranked.len() == 2 && ranked[0].index != 0 {
            return Err("equal ranks must preserve catalog order".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rank_ordering_matches_attribute_ordering() {
    forall("rank order == availableSpace order", cfg(150), |rng| {
        let n = 2 + rng.index(8);
        let spaces: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1e9)).collect();
        let ads: Vec<_> = spaces
            .iter()
            .map(|s| AdBuilder::new().real("availableSpace", *s).build())
            .collect();
        let request = parse_classad("rank = other.availableSpace;").unwrap();
        let ranked = rank_candidates(&request, &ads);
        for w in ranked.windows(2) {
            if w[0].rank < w[1].rank {
                return Err(format!("rank order violated: {} < {}", w[0].rank, w[1].rank));
            }
        }
        let best = ranked[0].index;
        let max = spaces
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        if (spaces[best] - max).abs() > 1e-9 {
            return Err("winner is not argmax(availableSpace)".into());
        }
        Ok(())
    });
}

#[test]
fn prop_three_valued_logic_never_panics_and_is_total() {
    // Random expressions over a small grammar evaluate to *some* value.
    forall("eval is total", cfg(300), |rng| {
        let atoms = ["1", "2.5", "\"x\"", "TRUE", "FALSE", "UNDEFINED", "ERROR", "missing", "5G"];
        let ops = ["+", "-", "*", "/", "==", "!=", "<", ">", "&&", "||", "=?="];
        let mut expr = (*rng.choose(&atoms)).to_string();
        for _ in 0..rng.index(6) {
            expr = format!("({expr} {} {})", rng.choose(&ops), rng.choose(&atoms));
        }
        let ad = parse_classad(&format!("x = {expr};")).map_err(|e| format!("{e}: {expr}"))?;
        let _ = ad.value("x"); // must not panic
        Ok(())
    });
}

#[test]
fn prop_ldif_round_trip() {
    forall("ldif round trip", cfg(200), |rng| {
        let n_entries = 1 + rng.index(4);
        let mut entries = Vec::new();
        for i in 0..n_entries {
            let mut e = Entry::new(Dn::parse(&format!("gss=v{i}, o=grid")).unwrap());
            e.add("objectClass", "GridStorageServerVolume");
            for a in 0..rng.index(6) {
                let val = match rng.index(3) {
                    0 => format!("{}", rng.range(-1e9, 1e9)),
                    1 => format!("str-{}", rng.below(1000)),
                    _ => " leading space needs b64".to_string(),
                };
                e.add(&format!("attr{a}"), val);
            }
            entries.push(e);
        }
        let text = to_ldif_stream(&entries);
        let parsed = parse_ldif(&text).map_err(|e| e.to_string())?;
        if parsed != entries {
            return Err("ldif round trip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dit_search_scope_containment() {
    // Sub results ⊇ One results ⊇ nothing outside base.
    forall("dit scope containment", cfg(100), |rng| {
        let mut dit = Dit::new();
        let orgs = ["anl", "lbl", "isi"];
        for org in orgs {
            for s in 0..(1 + rng.index(3)) {
                let dn = Dn::parse(&format!("gss=v{s}, o={org}, o=grid")).unwrap();
                let mut e = Entry::new(dn);
                e.add("objectClass", "GridStorageServerVolume");
                e.put_f64("availableSpace", rng.range(0.0, 100.0));
                dit.add_with_ancestors(e).unwrap();
            }
        }
        let base = Dn::parse(&format!("o={}, o=grid", rng.choose(&orgs))).unwrap();
        let all = Filter::parse("(objectClass=*)").unwrap();
        let sub = dit.search(&base, Scope::Sub, &all);
        let one = dit.search(&base, Scope::One, &all);
        for e in &one {
            if !sub.iter().any(|s| s.dn == e.dn) {
                return Err("One result missing from Sub".into());
            }
        }
        for e in &sub {
            if !e.dn.under(&base) {
                return Err(format!("entry {} escapes base {base}", e.dn));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_forecast_bank_invariants() {
    forall("forecast bank invariants", cfg(200), |rng| {
        let n = rng.index(50);
        let hist: Vec<f64> = (0..n).map(|_| rng.range(1.0, 1e6)).collect();
        let mask: Vec<f64> = (0..n).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect();
        let out = forecast_bank(&hist, &mask);
        let lo = hist
            .iter()
            .zip(&mask)
            .filter(|(_, m)| **m > 0.5)
            .map(|(h, _)| *h)
            .fold(f64::INFINITY, f64::min);
        let hi = hist
            .iter()
            .zip(&mask)
            .filter(|(_, m)| **m > 0.5)
            .map(|(h, _)| *h)
            .fold(f64::NEG_INFINITY, f64::max);
        for (p, v) in out.preds.iter().enumerate() {
            if !v.is_finite() {
                return Err(format!("predictor {p} not finite"));
            }
            if lo.is_finite() && (*v < lo - 1e-6 || *v > hi + 1e-6) {
                return Err(format!(
                    "predictor {p} = {v} outside observed range [{lo}, {hi}]"
                ));
            }
        }
        for (p, m) in out.mses.iter().enumerate() {
            if *m < 0.0 || !m.is_finite() {
                return Err(format!("mse {p} = {m} invalid"));
            }
        }
        if out.mses[out.best_index()] > out.mses.iter().cloned().fold(f64::INFINITY, f64::min) {
            return Err("best_index is not argmin".into());
        }
        Ok(())
    });
}

/// A deterministic flat topology for flow properties: per-site link
/// rates are fixed (no noise/diurnal/congestion), so capacity bounds
/// are exact.
fn flow_topo(rng: &mut Rng, n: usize) -> (Topology, Vec<f64>) {
    let mut cfg = GridConfig::generate(n, 1000 + rng.below(10_000));
    let mut rates = Vec::with_capacity(n);
    for s in &mut cfg.sites {
        s.wan_bandwidth = rng.range(0.2e6, 3e6);
        s.diurnal_amp = 0.0;
        s.noise_frac = 0.0;
        s.congestion_prob = 0.0;
        s.ar_coeff = 0.0;
        s.latency = 0.0;
        s.drd_time_ms = 0.0;
        s.disk_rate = 1e9;
        rates.push(s.wan_bandwidth);
    }
    (Topology::build(&cfg), rates)
}

#[test]
fn prop_flowset_bandwidth_conservation() {
    // The shared-kernel invariant (ISSUE 4): at every instant, the sum
    // of flow rates never exceeds (a) any site link's capacity or
    // (b) any downlink group's cap — under randomized flows, groups,
    // leads, advances and cancels.
    forall("flowset conservation", cfg(80), |rng| {
        let n_sites = 2 + rng.index(4);
        let (mut topo, rates) = flow_topo(rng, n_sites);
        let mut fs = FlowSet::new(rng.range(0.1e6, 4e6));
        let n_groups = 1 + rng.index(3);
        for _ in 1..n_groups {
            fs.add_group(if rng.chance(0.3) {
                f64::INFINITY
            } else {
                rng.range(0.1e6, 4e6)
            });
        }
        let n_flows = 1 + rng.index(8);
        let mut ids = Vec::new();
        for _ in 0..n_flows {
            let site = rng.index(n_sites);
            let group = rng.index(n_groups);
            // Per the sharing convention, every stream registers.
            topo.begin_transfer(site);
            ids.push(fs.add_in(
                &topo,
                site,
                rng.range(1e5, 4e6),
                if rng.chance(0.3) { rng.range(0.0, 2.0) } else { 0.0 },
                group,
            ));
        }
        for _ in 0..12 {
            let bws = fs.bandwidths(&mut topo);
            let mut per_site = vec![0.0f64; n_sites];
            let mut per_group = vec![0.0f64; n_groups];
            for &(id, bw) in &bws {
                if bw < 0.0 {
                    return Err(format!("negative rate {bw} on flow {id}"));
                }
                per_site[fs.flow(id).site] += bw;
                per_group[fs.flow(id).group] += bw;
            }
            for (s, &sum) in per_site.iter().enumerate() {
                // k registered streams on one link share k/(k+1) of the
                // sampled rate, so the raw link rate bounds the sum.
                if sum > rates[s] * (1.0 + 1e-9) {
                    return Err(format!("site {s} oversubscribed: {sum} > {}", rates[s]));
                }
            }
            for (g, &sum) in per_group.iter().enumerate() {
                if sum > fs.group_cap(g) * (1.0 + 1e-9) {
                    return Err(format!(
                        "group {g} over its downlink: {sum} > {}",
                        fs.group_cap(g)
                    ));
                }
            }
            // Random walk: advance, sometimes cancel a live flow.
            fs.advance(&mut topo, rng.range(0.05, 1.5));
            if rng.chance(0.2) {
                let id = ids[rng.index(ids.len())];
                if fs.flow(id).finished_at.is_none() && !fs.flow(id).cancelled {
                    fs.cancel(id);
                    topo.end_transfer(fs.flow(id).site);
                }
            }
            // Byte accounting never goes backwards or overshoots.
            for &id in &ids {
                let f = fs.flow(id);
                if f.delivered < -1e-9 || f.remaining < -1e-9 {
                    return Err(format!("negative accounting on flow {id}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_flowset_no_starvation() {
    // Every flow from a live site eventually completes — under random
    // arrivals, cancels, per-group caps and an optional replica-death
    // fault. Dead-site flows stall (never complete) but must not stop
    // time or peers.
    forall("flowset no starvation", cfg(60), |rng| {
        let n_sites = 2 + rng.index(4);
        let (mut topo, _) = flow_topo(rng, n_sites);
        // A site may die at a random instant.
        let dead_site = if rng.chance(0.4) {
            let s = rng.index(n_sites);
            topo.schedule_fault(s, rng.range(0.0, 5.0), FaultKind::ReplicaDeath);
            Some(s)
        } else {
            None
        };
        let mut fs = FlowSet::new(rng.range(0.2e6, 2e6));
        let g2 = fs.add_group(f64::INFINITY);
        let n_flows = 1 + rng.index(6);
        let mut ids = Vec::new();
        let mut cancelled = Vec::new();
        for k in 0..n_flows {
            let site = rng.index(n_sites);
            topo.begin_transfer(site);
            let id = fs.add_in(
                &topo,
                site,
                rng.range(1e5, 2e6),
                rng.range(0.0, 1.0),
                if k % 2 == 0 { 0 } else { g2 },
            );
            ids.push(id);
            // Staggered arrivals + occasional cancels mid-run.
            let step = rng.range(0.1, 2.0);
            fs.advance(&mut topo, step);
            // Only flows still in flight can be cancelled; one that
            // already finished keeps its full accounting checks below.
            if rng.chance(0.15) && fs.flow(id).finished_at.is_none() {
                fs.cancel(id);
                topo.end_transfer(site);
                cancelled.push(id);
            }
        }
        // Generous horizon: total bytes over the slowest possible
        // aggregate path, plus leads and slack.
        let t_end = topo.now + (n_flows as f64 * 2e6) / 0.2e6 * (n_flows as f64) + 60.0;
        let mut guard = 0;
        while fs.live() > 0 && topo.now < t_end {
            fs.advance(&mut topo, 5.0);
            guard += 1;
            if guard > 100_000 {
                return Err("advance loop did not converge".into());
            }
        }
        for &id in &ids {
            let f = fs.flow(id);
            if cancelled.contains(&id) {
                if f.finished_at.is_some() && f.cancelled {
                    return Err(format!("cancelled flow {id} also completed"));
                }
                continue;
            }
            let from_dead = Some(f.site) == dead_site;
            match f.finished_at {
                // A dead-site flow may still complete legitimately if
                // it drained before the death instant; the accounting
                // checks below cover that case.
                Some(at) => {
                    if at < f.started_at - 1e-9 {
                        return Err(format!("flow {id} finished before it started"));
                    }
                    if (f.delivered + f.remaining) < 1e5 - 1.0 {
                        return Err(format!("flow {id} lost bytes"));
                    }
                    if f.remaining > 1e-6 {
                        return Err(format!("flow {id} finished with bytes left"));
                    }
                }
                None => {
                    if !from_dead {
                        return Err(format!(
                            "live-site flow {id} starved (site {}, delivered {})",
                            f.site, f.delivered
                        ));
                    }
                }
            }
        }
        // Time always advanced past stalls.
        if fs.live() > 0 && topo.now < t_end {
            return Err("clock stopped with live flows".into());
        }
        Ok(())
    });
}

#[test]
fn prop_directory_fanout_cap_completion_determinism() {
    // The event-driven fan-out contract (ISSUE 5): in-flight never
    // exceeds the cap; every query completes (response or explicit
    // timeout/cutoff) regardless of latency ordering; and a fixed
    // input replays bit-identically.
    forall("fanout cap/completion/determinism", cfg(120), |rng| {
        let t0 = rng.range(0.0, 1e4);
        let n_sites = 1 + rng.index(24);
        let sites: Vec<(usize, f64)> = (0..n_sites)
            .map(|i| (i, rng.range(0.0, 5.0)))
            .collect();
        let cap = 1 + rng.index(6);
        let deadline = if rng.chance(0.3) { rng.range(0.5, 4.0) } else { f64::INFINITY };
        let cutoff = if rng.chance(0.3) { rng.range(0.5, 8.0) } else { f64::INFINITY };
        let max_retries = if deadline.is_finite() && rng.chance(0.5) { rng.index(3) } else { 0 };
        let policy = FanoutPolicy {
            max_in_flight: cap,
            per_query_deadline: deadline,
            straggler_cutoff: cutoff,
            max_retries,
            retry_backoff: if max_retries > 0 { rng.range(0.0, 1.0) } else { 0.0 },
        };
        let f1 = run_fanout(t0, &sites, policy);
        if !f1.finished() {
            return Err("fan-out never finished".into());
        }
        if f1.peak_in_flight() > cap {
            return Err(format!("in-flight peak {} > cap {cap}", f1.peak_in_flight()));
        }
        let responses = f1.responses();
        if responses.len() + f1.unresolved().len() != n_sites {
            return Err(format!(
                "{} responses + {} unresolved != {n_sites} sites",
                responses.len(),
                f1.unresolved().len()
            ));
        }
        if deadline.is_infinite() && cutoff.is_infinite() && !f1.unresolved().is_empty() {
            return Err("unbounded fan-out left queries unresolved".into());
        }
        for &(site, at) in &responses {
            let latency = sites[site].1;
            // With retries, the total waiting budget per site is one
            // deadline per attempt (server-side progress carries over).
            if latency > deadline * (1.0 + max_retries as f64) + 1e-9 {
                return Err(format!("site {site} answered past its retry budget"));
            }
            if at > t0 + cutoff + 1e-9 {
                return Err(format!("site {site} answered after the cutoff"));
            }
            if at < t0 + latency - 1e-9 {
                return Err(format!("site {site} answered before its latency elapsed"));
            }
        }
        let f2 = run_fanout(t0, &sites, policy);
        if f2.responses() != responses || f2.finished_at() != f1.finished_at() {
            return Err("fan-out not deterministic for a fixed input".into());
        }
        Ok(())
    });
}

#[test]
fn prop_concurrent_fanouts_share_one_kernel_without_crosstalk() {
    // Two fan-outs on one engine with one id allocator: every event
    // routes to exactly one owner, both finish, neither sees the
    // other's sites.
    forall("fanout shared-kernel routing", cfg(60), |rng| {
        let (mut topo, _) = flow_topo(rng, 2);
        let mut eng = Engine::new(FlowSet::new(f64::INFINITY));
        let mut ids = QueryIds::new();
        let mk_sites = |rng: &mut Rng, n: usize| -> Vec<(usize, f64)> {
            (0..n).map(|i| (i, rng.range(0.1, 3.0))).collect()
        };
        let na = 1 + rng.index(8);
        let sa = mk_sites(rng, na);
        let nb = 1 + rng.index(8);
        let sb = mk_sites(rng, nb);
        let pol = FanoutPolicy { max_in_flight: 1 + rng.index(3), ..Default::default() };
        let now = topo.now;
        let mut a = DirectoryFanout::start(&mut eng, &mut ids, now, &sa, pol);
        let mut b = DirectoryFanout::start(&mut eng, &mut ids, now, &sb, pol);
        let a_ids: std::collections::BTreeSet<u64> = a.qids().into_iter().collect();
        let b_ids: std::collections::BTreeSet<u64> = b.qids().into_iter().collect();
        if a_ids.intersection(&b_ids).next().is_some() {
            return Err("fan-outs share query ids".into());
        }
        let mut guard = 0;
        while !(a.finished() && b.finished()) {
            guard += 1;
            if guard > 10_000 {
                return Err("shared kernel never drained".into());
            }
            match eng.next(&mut topo) {
                Some(Signal::Query { id, at }) => {
                    if a_ids.contains(&id) {
                        a.on_query(&mut eng, id, at);
                    } else if b_ids.contains(&id) {
                        b.on_query(&mut eng, id, at);
                    } else {
                        return Err(format!("orphan query id {id}"));
                    }
                }
                Some(_) => continue,
                None => return Err("kernel drained before fan-outs finished".into()),
            }
        }
        if a.responses().len() != sa.len() || b.responses().len() != sb.len() {
            return Err("a fan-out lost responses to its neighbour".into());
        }
        Ok(())
    });
}

#[test]
fn prop_match_context_attribute_resolution() {
    // other.X in the request always sees the storage value, regardless
    // of name collisions with the request's own attributes.
    forall("other-scope resolution", cfg(150), |rng| {
        let v_req = rng.range(0.0, 1e6);
        let v_sto = rng.range(0.0, 1e6);
        let request = parse_classad(&format!(
            "availableSpace = {v_req}; probe = other.availableSpace;"
        ))
        .unwrap();
        let storage = parse_classad(&format!("availableSpace = {v_sto};")).unwrap();
        match eval_in_match(&request, &storage, "probe") {
            Value::Real(got) if (got - v_sto).abs() < 1e-9 => Ok(()),
            other => Err(format!("probe = {other:?}, want {v_sto}")),
        }
    });
}

/// Shared attribute-name pool for the differential generator: both ads
/// draw definitions and references from the same eight names, so
/// cross-ad chains and genuine cycles (self- and mutual) arise often.
const DIFF_POOL: [&str; 8] = ["pa0", "pa1", "pa2", "pa3", "pa4", "pa5", "pa6", "pa7"];

fn gen_diff_value(rng: &mut Rng) -> Value {
    match rng.index(7) {
        0 => Value::Int(rng.below(200) as i64 - 100),
        1 => Value::Real(rng.range(-100.0, 100.0)),
        2 => Value::Bool(rng.chance(0.5)),
        3 => Value::Str(format!("s{}", rng.below(4))),
        4 => Value::Undefined,
        5 => Value::Error,
        _ => Value::Quantity { base: rng.range(0.0, 1e6), rate: rng.chance(0.5) },
    }
}

fn gen_diff_attr(rng: &mut Rng) -> Expr {
    let scope = match rng.index(3) {
        0 => AdScope::My,
        1 => AdScope::Other,
        _ => AdScope::Default,
    };
    Expr::Attr(scope, AttrName::new(*rng.choose(&DIFF_POOL)))
}

fn gen_diff_expr(rng: &mut Rng, depth: usize) -> Expr {
    if depth == 0 || rng.chance(0.3) {
        return if rng.chance(0.5) { Expr::Lit(gen_diff_value(rng)) } else { gen_diff_attr(rng) };
    }
    match rng.index(10) {
        0 => Expr::Unary(
            *rng.choose(&[UnOp::Not, UnOp::Neg, UnOp::BitNot]),
            Box::new(gen_diff_expr(rng, depth - 1)),
        ),
        1..=5 => {
            let op = *rng.choose(&[
                BinOp::And,
                BinOp::Or,
                BinOp::Eq,
                BinOp::Ne,
                BinOp::Lt,
                BinOp::Le,
                BinOp::Gt,
                BinOp::Ge,
                BinOp::Is,
                BinOp::Isnt,
                BinOp::Add,
                BinOp::Sub,
                BinOp::Mul,
                BinOp::Div,
                BinOp::Mod,
            ]);
            Expr::Binary(
                op,
                Box::new(gen_diff_expr(rng, depth - 1)),
                Box::new(gen_diff_expr(rng, depth - 1)),
            )
        }
        6 => Expr::Cond(
            Box::new(gen_diff_expr(rng, depth - 1)),
            Box::new(gen_diff_expr(rng, depth - 1)),
            Box::new(gen_diff_expr(rng, depth - 1)),
        ),
        7 => {
            // Builtins, including a deliberately invalid regex pattern.
            match rng.index(5) {
                0 => Expr::Call(
                    "regexp".into(),
                    vec![
                        Expr::Lit(Value::Str(
                            rng.choose(&["s[0-9]+", "^s.*", "bad("]).to_string(),
                        )),
                        gen_diff_expr(rng, depth - 1),
                    ],
                ),
                1 => Expr::Call(
                    "strcat".into(),
                    vec![gen_diff_expr(rng, depth - 1), gen_diff_expr(rng, depth - 1)],
                ),
                2 => Expr::Call(
                    "min".into(),
                    vec![gen_diff_expr(rng, depth - 1), gen_diff_expr(rng, depth - 1)],
                ),
                3 => Expr::Call("isundefined".into(), vec![gen_diff_expr(rng, depth - 1)]),
                _ => Expr::Call(
                    "member".into(),
                    vec![
                        gen_diff_expr(rng, depth - 1),
                        Expr::List(vec![
                            Expr::Lit(Value::Int(1)),
                            gen_diff_expr(rng, depth - 1),
                        ]),
                    ],
                ),
            }
        }
        8 => Expr::List(
            (0..rng.index(3)).map(|_| gen_diff_expr(rng, depth - 1)).collect(),
        ),
        _ => gen_diff_attr(rng),
    }
}

/// An ad over the shared pool: each attribute is a literal or a small
/// expression (which may reference pool names in any scope — including
/// itself, for guaranteed self-cycles).
fn gen_diff_ad(rng: &mut Rng, request: bool) -> ClassAd {
    let mut ad = ClassAd::new();
    for (i, name) in DIFF_POOL.iter().enumerate() {
        if rng.chance(0.6) {
            let defn = if rng.chance(0.5) {
                Expr::Lit(gen_diff_value(rng))
            } else if rng.chance(0.15) {
                // Deliberate self-cycle.
                Expr::Attr(AdScope::Default, AttrName::new(*name))
            } else {
                gen_diff_expr(rng, 1 + i % 2)
            };
            ad.set(*name, defn);
        }
    }
    if request {
        if rng.chance(0.9) {
            ad.set("requirements", gen_diff_expr(rng, 3));
        }
        if rng.chance(0.9) {
            ad.set("rank", gen_diff_expr(rng, 3));
        }
    }
    ad
}

#[test]
fn prop_vm_is_bit_identical_to_tree_walk() {
    // The PR 9 parity pin: over randomized ads (literals, scoped attr
    // refs, arithmetic, comparisons, boolean ops, regexp(), deliberate
    // cycles), the bytecode VM — ad mode and table mode — must agree
    // with the tree-walking reference evaluator on every match verdict
    // and on the exact bits of every rank.
    forall("vm == tree-walk differential", cfg(250), |rng| {
        let request = gen_diff_ad(rng, true);
        let candidates: Vec<ClassAd> = (0..1 + rng.index(4)).map(|_| gen_diff_ad(rng, false)).collect();
        let compiled = CompiledMatch::compile(&request);
        let mut vm = VmScratch::default();
        let mut table = CandidateTable::default();
        table.rebuild(compiled.program(), candidates.iter());
        for (i, c) in candidates.iter().enumerate() {
            let want = compiled.matches(c);
            if compiled.matches_vm(c, &mut vm) != want {
                return Err(format!("vm verdict != tree on candidate {i}\nrequest: {request}\ncandidate: {c}"));
            }
            if compiled.matches_vm_row(c, &table, i, &mut vm) != want {
                return Err(format!("vm table verdict != tree on candidate {i}\nrequest: {request}\ncandidate: {c}"));
            }
            let tree_bits = compiled.rank(c).to_bits();
            let vm_bits = compiled.rank_vm(c, &mut vm).to_bits();
            if vm_bits != tree_bits {
                return Err(format!(
                    "vm rank bits {vm_bits:#x} != tree {tree_bits:#x} on candidate {i}\nrequest: {request}\ncandidate: {c}"
                ));
            }
        }
        // Batch pass: compare (index, rank-bits) pairs — NaN-safe.
        let (flags, ms) = compiled.match_and_rank(candidates.iter());
        let (mut vflags, mut vms) = (Vec::new(), Vec::new());
        compiled.match_and_rank_vm_into(
            candidates.iter(),
            Some(&table),
            &mut vflags,
            &mut vms,
            &mut vm,
        );
        if flags != vflags {
            return Err(format!("batch flags diverged\nrequest: {request}"));
        }
        let key = |ms: &[globus_replica::classad::Match]| -> Vec<(usize, u64)> {
            ms.iter().map(|m| (m.index, m.rank.to_bits())).collect()
        };
        if key(&ms) != key(&vms) {
            return Err(format!("batch ranking diverged\nrequest: {request}"));
        }
        Ok(())
    });
}

#[test]
fn prop_traced_open_loop_runs_are_byte_identical() {
    // The determinism contract the flight recorder rides on (P8):
    // identically seeded open-loop runs, each with a fresh recorder,
    // export byte-identical JSONL and Chrome traces — the simulated
    // clock, the event order, and the name-interning order are all
    // functions of the seed alone.
    forall("traced open-loop determinism", cfg(5), |rng| {
        let n = 4 + rng.index(4);
        let seed = 5000 + rng.below(10_000);
        let grid_cfg = GridConfig::generate(n, seed);
        let spec = WorkloadSpec { files: 6, mean_interarrival: 40.0, ..Default::default() };
        let mut wl = Workload::new(spec.clone(), seed);
        let reqs = wl.take(10);
        let run = || {
            let trace = TraceHandle::new(1 << 16);
            let opts = OpenLoopOptions {
                trace: trace.clone(),
                sample_period: 25.0,
                ..OpenLoopOptions::open()
            };
            let report = run_quality_open(
                &grid_cfg,
                &spec,
                &reqs,
                3,
                2,
                SelectorKind::Forecast,
                &opts,
                None,
            );
            let (jsonl, chrome) = trace.read(|r| (r.jsonl(), r.chrome_json())).unwrap();
            (report.quality.mean_time, report.quality.p95_time, jsonl, chrome)
        };
        let (mean_a, p95_a, jsonl_a, chrome_a) = run();
        let (mean_b, p95_b, jsonl_b, chrome_b) = run();
        if mean_a != mean_b || p95_a != p95_b {
            return Err(format!(
                "reports diverged: mean {mean_a} vs {mean_b}, p95 {p95_a} vs {p95_b}"
            ));
        }
        if jsonl_a != jsonl_b {
            return Err("JSONL exports diverged".into());
        }
        if chrome_a != chrome_b {
            return Err("Chrome exports diverged".into());
        }
        if jsonl_a.is_empty() {
            return Err("traced run recorded nothing".into());
        }
        Ok(())
    });
}

#[test]
fn prop_flowset_conservation_across_fault_and_heal_boundaries() {
    // ISSUE 7: interval faults (crash→recover, degrade→restore) split
    // the flow integrator at every boundary. Under random schedules
    // and coarse steps that straddle the boundaries: no bytes move
    // while a site is down, aggregate rates respect the *degraded*
    // link rate, delivered bytes stay monotone and conserved, and
    // once the weather clears every flow drains.
    forall("flowset conservation across heals", cfg(40), |rng| {
        let n_sites = 2 + rng.index(3);
        let (mut topo, rates) = flow_topo(rng, n_sites);
        for s in 0..n_sites {
            if rng.chance(0.7) {
                let at = rng.range(0.5, 10.0);
                let down = rng.range(1.0, 15.0);
                if rng.chance(0.5) {
                    topo.schedule_fault_for(s, at, down, FaultKind::ReplicaDeath);
                } else {
                    topo.schedule_fault_for(
                        s,
                        at,
                        down,
                        FaultKind::LinkDegrade { factor: rng.range(0.05, 0.8) },
                    );
                }
            }
        }
        let mut fs = FlowSet::new(f64::INFINITY);
        let mut ids = Vec::new();
        let mut totals = Vec::new();
        for _ in 0..(1 + rng.index(6)) {
            let site = rng.index(n_sites);
            topo.begin_transfer(site);
            let bytes = rng.range(1e5, 6e6);
            ids.push(fs.add_in(&topo, site, bytes, 0.0, 0));
            totals.push(bytes);
        }
        let mut last_delivered = vec![0.0f64; ids.len()];
        for _ in 0..40 {
            let bws = fs.bandwidths(&mut topo);
            let mut per_site = vec![0.0f64; n_sites];
            for &(id, bw) in &bws {
                if bw < 0.0 {
                    return Err(format!("negative rate on flow {id}"));
                }
                per_site[fs.flow(id).site] += bw;
            }
            for (s, &sum) in per_site.iter().enumerate() {
                if !topo.site_alive(s) {
                    if sum > 1e-9 {
                        return Err(format!("dead site {s} still moving {sum} B/s"));
                    }
                    continue;
                }
                // Registered streams share k/(k+1) of the link, so the
                // degraded raw rate bounds the aggregate.
                let cap = rates[s] * topo.degrade_factor(s);
                if sum > cap * (1.0 + 1e-6) + 1.0 {
                    return Err(format!(
                        "site {s} over its degraded link at t={}: {sum} > {cap}",
                        topo.now
                    ));
                }
            }
            fs.advance(&mut topo, rng.range(0.1, 1.2));
            for (k, &id) in ids.iter().enumerate() {
                let f = fs.flow(id);
                if f.delivered + 1e-6 < last_delivered[k] {
                    return Err(format!("flow {id} delivered went backwards"));
                }
                last_delivered[k] = f.delivered;
                if f.delivered + f.remaining > totals[k] + 1.0 {
                    return Err(format!("flow {id} invented bytes"));
                }
            }
        }
        // All weather is over by t=25; every flow must now drain.
        let t_end = topo.now + 600.0;
        let mut guard = 0;
        while fs.live() > 0 && topo.now < t_end {
            fs.advance(&mut topo, 2.0);
            guard += 1;
            if guard > 100_000 {
                return Err("post-heal drain did not converge".into());
            }
        }
        for (k, &id) in ids.iter().enumerate() {
            let f = fs.flow(id);
            if f.finished_at.is_none() {
                return Err(format!("flow {id} never finished after all heals"));
            }
            if (f.delivered - totals[k]).abs() > 1.0 {
                return Err(format!(
                    "flow {id} delivered {} of {} bytes",
                    f.delivered, totals[k]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_open_loop_accounting_balances_under_random_weather() {
    // ISSUE 7: whatever the weather, every admitted request ends in
    // exactly one of {finished, skipped, gave up} — none lost, none
    // double-counted — with retry/failover on or off, and gave-ups
    // can only exist when retry is enabled.
    forall("weather request accounting", cfg(12), |rng| {
        let grid_cfg = GridConfig::generate(3 + rng.index(3), 2000 + rng.below(10_000));
        let spec = WorkloadSpec {
            files: 4,
            mean_interarrival: rng.range(10.0, 40.0),
            ..Default::default()
        };
        let n_requests = 6 + rng.index(6);
        let reqs = Workload::new(spec.clone(), grid_cfg.seed).take(n_requests);
        let wspec = WeatherSpec {
            horizon: 1200.0,
            mtbf: rng.range(100.0, 600.0),
            mttr: rng.range(20.0, 120.0),
            perm_frac: rng.range(0.0, 0.5),
            flap_rate: if rng.chance(0.5) { 1.0 / rng.range(100.0, 500.0) } else { 0.0 },
            flap_duration: 40.0,
            flap_floor: 0.05,
        };
        let plan = WeatherPlan::generate(&wspec, grid_cfg.sites.len(), rng.below(1 << 20));
        let retry_on = rng.chance(0.7);
        let retry = retry_on.then(|| RetryOptions {
            transfer_timeout: rng.range(15.0, 60.0),
            max_attempts: 1 + rng.below(4) as u32,
            backoff_base: rng.range(0.5, 4.0),
            ..Default::default()
        });
        let opts = OpenLoopOptions {
            retry,
            faults: plan.faults.clone(),
            ..OpenLoopOptions::open()
        };
        let report = run_quality_open(
            &grid_cfg,
            &spec,
            &reqs,
            3,
            2,
            SelectorKind::Forecast,
            &opts,
            None,
        );
        let accounted = report.quality.requests + report.skipped + report.gave_up;
        if accounted != n_requests {
            return Err(format!(
                "{} finished + {} skipped + {} gave up != {n_requests} admitted",
                report.quality.requests, report.skipped, report.gave_up
            ));
        }
        if !retry_on && (report.gave_up > 0 || report.retries > 0 || report.failovers > 0)
        {
            return Err("retry counters nonzero with retry disabled".into());
        }
        if report.failovers > report.retries {
            return Err(format!(
                "failovers {} exceed retries {}",
                report.failovers, report.retries
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_space_accounting_stays_within_bounds_under_random_churn() {
    // ISSUE 10: the space-accounting bug class. Under random
    // interleavings of replica creation, deletion and raw (possibly
    // absurd) space deltas: per-site `used` stays inside
    // [0, total_space], `consume_space` reports exactly the delta it
    // applied, the creation ledger only covers live (file, site)
    // placements with non-negative amounts, placement and catalog
    // agree, and no file ever loses its last copy.
    forall("space ledger churn", cfg(20), |rng| {
        let grid_cfg = GridConfig::generate(3 + rng.index(3), 3000 + rng.below(10_000));
        let spec = WorkloadSpec { files: 3 + rng.index(3), ..Default::default() };
        let mut g = SimGrid::build(&grid_cfg, &spec, 1 + rng.index(2), 16);
        g.warm(2);
        let check = |g: &SimGrid| -> Result<(), String> {
            for i in 0..g.topo.len() {
                let s = g.topo.site(i);
                if s.used < -1e-6 {
                    return Err(format!("site {i} used went negative: {}", s.used));
                }
                if s.used > s.cfg.total_space + 1e-6 {
                    return Err(format!(
                        "site {i} over capacity: {} > {}",
                        s.used, s.cfg.total_space
                    ));
                }
            }
            for (&(f, s), &amt) in &g.space_ledger {
                if amt < 0.0 {
                    return Err(format!("negative ledger amount for ({f},{s}): {amt}"));
                }
                if !g.placement[f].contains(&s) {
                    return Err(format!("ledger entry ({f},{s}) has no placement"));
                }
            }
            let cat = g.catalog.lock().unwrap();
            for (f, name) in g.files.iter().enumerate() {
                let copies = cat.locate(name).map_err(|e| e.to_string())?.len();
                if copies != g.placement[f].len() {
                    return Err(format!(
                        "file {f}: catalog has {copies} copies, placement {}",
                        g.placement[f].len()
                    ));
                }
                if copies == 0 {
                    return Err(format!("file {f} lost its last copy"));
                }
            }
            Ok(())
        };
        check(&g)?;
        for _ in 0..30 {
            let f = rng.index(g.files.len());
            let logical = g.files[f].clone();
            match rng.index(4) {
                0 | 1 => {
                    let policy = if rng.chance(0.5) {
                        PlacementPolicy::MostSpace
                    } else {
                        PlacementPolicy::FastestWrite
                    };
                    // May legitimately fail (no site fits); the
                    // invariants must hold either way.
                    let _ = ReplicaManager::new(&mut g, policy).create_replica(&logical);
                }
                2 => {
                    let holders = g.placement[f].clone();
                    if !holders.is_empty() {
                        let site = holders[rng.index(holders.len())];
                        let name = g.topo.site(site).cfg.name.clone();
                        // The last-copy guard may refuse; never forced.
                        let _ = ReplicaManager::new(&mut g, PlacementPolicy::MostSpace)
                            .delete_replica(&logical, &name);
                    }
                }
                _ => {
                    let i = rng.index(g.topo.len());
                    let before = g.topo.site(i).used;
                    let raw = rng.range(-2.0, 2.0) * g.topo.site(i).cfg.total_space;
                    let applied = g.topo.consume_space(i, raw);
                    let after = g.topo.site(i).used;
                    if (after - before - applied).abs() > 1e-3 {
                        return Err(format!(
                            "consume_space lied: moved {} but reported {applied}",
                            after - before
                        ));
                    }
                }
            }
            check(&g)?;
        }
        Ok(())
    });
}
