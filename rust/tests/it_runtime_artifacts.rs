//! Integration: the PJRT runtime against the AOT artifacts, and the
//! cross-language contract — the Rust predictor bank and the Pallas
//! kernel (through the compiled artifact) must agree.
//!
//! Requires `make artifacts`; each test skips (with a notice) when the
//! artifacts are absent so `cargo test` stays runnable pre-build.

use globus_replica::forecast::forecast_bank;
use globus_replica::runtime::engine::EngineHandle;
use globus_replica::runtime::Manifest;
use globus_replica::util::prng::Rng;

fn engine() -> Option<std::sync::Arc<EngineHandle>> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(EngineHandle::spawn(dir).expect("engine must load when artifacts exist"))
}

#[test]
fn engine_loads_and_reports_aot_shapes() {
    let Some(e) = engine() else { return };
    assert_eq!(e.aot_sites, 128);
    assert_eq!(e.aot_window, 64);
    assert_eq!(e.num_predictors, 8);
}

#[test]
fn forecast_artifact_agrees_with_rust_bank() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(77);
    // 10 sites with varying history lengths, values at realistic
    // bandwidth magnitudes.
    let hist: Vec<Vec<f64>> = (0..10)
        .map(|i| {
            let n = 3 + (i * 7) % 60;
            (0..n).map(|_| rng.range(10e3, 900e3)).collect()
        })
        .collect();
    let load: Vec<f64> = (0..10).map(|i| (i as f64) / 12.0).collect();
    let out = e.forecast(&hist, &load).expect("forecast");
    for (i, series) in hist.iter().enumerate() {
        let mask = vec![1.0; series.len()];
        let want = forecast_bank(series, &mask);
        for p in 0..8 {
            let got = out.preds[i][p] as f64;
            let rel = (got - want.preds[p]).abs() / want.preds[p].abs().max(1.0);
            assert!(
                rel < 1e-3,
                "site {i} predictor {p}: pjrt {got} vs rust {}",
                want.preds[p]
            );
        }
        // Effective bandwidth = best * (1 - load), f32 tolerance.
        let eff_want = want.best() * (1.0 - load[i]);
        let rel = (out.eff[i] as f64 - eff_want).abs() / eff_want.abs().max(1.0);
        assert!(rel < 2e-3, "site {i} eff: {} vs {eff_want}", out.eff[i]);
    }
}

#[test]
fn forecast_batches_beyond_aot_sites() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(78);
    let n = 200; // > 128 AOT rows -> two chunks
    let hist: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..16).map(|_| rng.range(1e4, 1e6)).collect())
        .collect();
    let load = vec![0.0; n];
    let out = e.forecast(&hist, &load).expect("forecast");
    assert_eq!(out.best.len(), n);
    // Chunked and unchunked slices agree.
    let out_head = e.forecast(&hist[..10], &load[..10]).unwrap();
    for i in 0..10 {
        assert_eq!(out.best[i], out_head.best[i]);
    }
}

#[test]
fn rank_artifact_scores_and_masks() {
    let Some(e) = engine() else { return };
    // 3 replicas x 2 attrs: [availableSpaceGB, bandwidthKBs].
    let attrs = vec![
        vec![50.0, 75.0],
        vec![3.0, 90.0],  // infeasible: space
        vec![80.0, 60.0],
    ];
    let lo = vec![vec![5.0, 50.0]];
    let hi = vec![vec![1e9, 1e9]];
    let weights = vec![vec![1.0, 0.0]]; // rank = availableSpace
    let out = e.rank(&attrs, &lo, &hi, &weights).expect("rank");
    assert_eq!(out.scores[0].len(), 3);
    assert!(out.scores[0][1] < -1e29, "infeasible must be -inf-ish");
    assert_eq!(out.best_idx[0], 2);
    assert!((out.best_score[0] - 80.0).abs() < 1e-3);
}

#[test]
fn rank_padding_rows_never_win() {
    let Some(e) = engine() else { return };
    // One mediocre but feasible replica; padding must not outrank it.
    let attrs = vec![vec![1.0, 1.0]];
    let lo = vec![vec![0.0, 0.0]];
    let hi = vec![vec![10.0, 10.0]];
    let weights = vec![vec![1.0, 1.0]];
    let out = e.rank(&attrs, &lo, &hi, &weights).expect("rank");
    assert_eq!(out.best_idx[0], 0);
    assert!((out.best_score[0] - 2.0).abs() < 1e-4);
}

#[test]
fn engine_is_shareable_across_threads() {
    let Some(e) = engine() else { return };
    let mut handles = Vec::new();
    for t in 0..4 {
        let e = e.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            for _ in 0..5 {
                let hist: Vec<Vec<f64>> =
                    (0..4).map(|_| (0..8).map(|_| rng.range(1e4, 1e6)).collect()).collect();
                let out = e.forecast(&hist, &[0.0, 0.1, 0.2, 0.3]).unwrap();
                assert_eq!(out.best.len(), 4);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn manifest_bank_matches_rust_constants() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let m = Manifest::load(dir).unwrap();
    assert_eq!(m.num_predictors, globus_replica::forecast::NUM_PREDICTORS);
    assert_eq!(
        m.predictor_names,
        vec![
            "last_value",
            "running_mean",
            "sliding_mean_4",
            "sliding_mean_16",
            "ema_0.10",
            "ema_0.30",
            "ema_0.60",
            "median_3"
        ]
    );
}
