//! Integration: GRIS/GIIS daemons over real TCP — the paper's §3/§5.1.2
//! search-phase machinery (broad GIIS discovery → GRIS drill-down →
//! LDIF → conversion).

use std::sync::{Arc, Mutex};

use globus_replica::broker::entries_to_candidate;
use globus_replica::classad::{parse_classad, symmetric_match};
use globus_replica::directory::client::DirectoryClient;
use globus_replica::directory::server::DirectoryServer;
use globus_replica::directory::{Dn, Entry, Filter, Giis, Gris, Scope};

fn demo_gris(org: &str, site: &str, avail_gb: f64) -> Gris {
    let mut gris = Gris::new(org, site);
    let base = gris.base_dn().clone();
    let vol = base.child("gss", "vol0");
    let mut e = Entry::new(vol.clone());
    e.add("objectClass", "GridStorageServerVolume");
    e.put_f64("totalSpace", 100.0 * 1024f64.powi(3));
    e.put_f64("availableSpace", avail_gb * 1024f64.powi(3));
    e.put("mountPoint", "/data");
    e.put_f64("diskTransferRate", 2e7);
    e.put_f64("drdTime", 8.0);
    e.put_f64("dwrTime", 9.0);
    gris.add_entry(e);
    let mut bw = Entry::new(vol.child("gss", "bw"));
    bw.add("objectClass", "GridStorageTransferBandwidth");
    for a in [
        "MaxRDBandwidth",
        "MinRDBandwidth",
        "AvgRDBandwidth",
        "MaxWRBandwidth",
        "MinWRBandwidth",
        "AvgWRBandwidth",
    ] {
        bw.put_f64(a, 64.0 * 1024.0);
    }
    gris.add_entry(bw);
    gris
}

#[test]
fn gris_search_over_tcp_round_trips_ldif() {
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(demo_gris("anl", "mcs", 50.0))), 0)
        .expect("bind");
    let mut client = DirectoryClient::connect(server.addr()).expect("connect");
    assert!(client.ping().unwrap());
    let entries = client
        .search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(objectClass=GridStorageServerVolume)").unwrap(),
        )
        .unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].f64("availableSpace").unwrap(), 50.0 * 1024f64.powi(3));
    assert_eq!(entries[0].first("mountPoint").unwrap(), "/data");
}

#[test]
fn filter_is_applied_server_side() {
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(demo_gris("anl", "mcs", 50.0))), 0)
        .expect("bind");
    let mut client = DirectoryClient::connect(server.addr()).expect("connect");
    let none = client
        .search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(availableSpace>=999999999999999)").unwrap(),
        )
        .unwrap();
    assert!(none.is_empty());
}

#[test]
fn giis_register_discover_drilldown() {
    // Two sites + an index: the full MDS discovery pattern.
    let gris_a = demo_gris("anl", "mcs", 50.0);
    let base_a = gris_a.base_dn().clone();
    let gris_b = demo_gris("lbl", "dsd", 80.0);
    let base_b = gris_b.base_dn().clone();
    let srv_a = DirectoryServer::spawn(Arc::new(Mutex::new(gris_a)), 0).unwrap();
    let srv_b = DirectoryServer::spawn(Arc::new(Mutex::new(gris_b)), 0).unwrap();
    let giis = DirectoryServer::spawn(Arc::new(Mutex::new(Giis::new())), 0).unwrap();

    let mut c = DirectoryClient::connect(giis.addr()).unwrap();
    c.register("mcs", srv_a.addr(), &base_a, vec![("availableGB".into(), "50".into())])
        .unwrap();
    c.register("dsd", srv_b.addr(), &base_b, vec![("availableGB".into(), "80".into())])
        .unwrap();
    assert_eq!(c.list().unwrap().len(), 2);

    let hits = c
        .discover(&Filter::parse("(availableGB>=60)").unwrap())
        .unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].first("site").unwrap(), "dsd");

    // Drill down to the winning site's GRIS.
    let addr = hits[0].first("addr").unwrap().to_string();
    let mut drill = DirectoryClient::connect(&addr).unwrap();
    let entries = drill
        .search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(objectClass=GridStorage*)").unwrap(),
        )
        .unwrap();
    assert_eq!(entries.len(), 2);
}

#[test]
fn tcp_entries_convert_and_match_like_local_ones() {
    // The full §5.1.2 pipeline over the wire: TCP search → LDIF →
    // ClassAd → matchmaking.
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(demo_gris("anl", "mcs", 50.0))), 0)
        .expect("bind");
    let mut client = DirectoryClient::connect(server.addr()).expect("connect");
    let entries = client
        .search(
            &Dn::parse("o=grid").unwrap(),
            Scope::Sub,
            &Filter::parse("(objectClass=GridStorage*)").unwrap(),
        )
        .unwrap();
    let cand = entries_to_candidate("mcs", "gsiftp://mcs/f", &entries);
    let request = parse_classad(
        r#"reqdSpace = 5G; reqdRDBandwidth = 50K/Sec;
           rank = other.availableSpace;
           requirement = other.availableSpace > 5G && other.MaxRDBandwidth > 50K/Sec;"#,
    )
    .unwrap();
    assert!(symmetric_match(&request, &cand.ad));
}

#[test]
fn concurrent_clients_are_served() {
    let server = Arc::new(
        DirectoryServer::spawn(Arc::new(Mutex::new(demo_gris("anl", "mcs", 50.0))), 0)
            .expect("bind"),
    );
    let addr = server.addr().to_string();
    let mut handles = Vec::new();
    for _ in 0..8 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = DirectoryClient::connect(&addr).unwrap();
            for _ in 0..20 {
                let entries = c
                    .search(
                        &Dn::parse("o=grid").unwrap(),
                        Scope::Sub,
                        &Filter::parse("(objectClass=*)").unwrap(),
                    )
                    .unwrap();
                assert_eq!(entries.len(), 5);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(server.served() >= 160);
}

#[test]
fn malformed_requests_get_err_not_hang() {
    use std::io::{BufRead, BufReader, Write};
    let server = DirectoryServer::spawn(Arc::new(Mutex::new(demo_gris("anl", "mcs", 1.0))), 0)
        .expect("bind");
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    stream.write_all(b"BOGUS\tverb\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR\t"), "got {line:?}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "."); // response terminator
    // Connection survives; a valid request still works.
    stream.write_all(b"PING\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "PONG");
}
