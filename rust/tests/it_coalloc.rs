//! Integration: co-allocated striped transfers end to end — broker
//! top-K selection → stripe plan → work-stealing scheduler over the
//! simulated grid — on a topology whose *predicted-best* link degrades
//! between selection and access (the scenario striping exists for).
//!
//! Acceptance (ISSUE 1): a co-allocated transfer of a large file from
//! ≥3 replicas completes faster, in simulated time, than the best
//! single-source fetch, and the scheduler's per-source instrumentation
//! lands in the same `HistoryStore` the GRIS providers read.

use globus_replica::broker::RankPolicy;
use globus_replica::classad::parse_classad;
use globus_replica::coalloc;
use globus_replica::config::{CoallocPolicy, GridConfig, SiteConfig};
use globus_replica::experiment::SimGrid;
use globus_replica::simnet::WorkloadSpec;

/// 4 sites: "hot" is the fastest on paper but rides a deep diurnal
/// swing; the three "flat" sites are a bit slower and steady. At the
/// diurnal trough the hot link collapses below the flat ones while its
/// *history* (gathered near the peak) still says it is the best.
fn degrading_grid() -> GridConfig {
    let site = |name: &str, wan: f64, amp: f64| SiteConfig {
        name: name.to_string(),
        org: "grid".to_string(),
        disk_rate: 1e8,
        total_space: 100.0 * 1024f64.powi(3),
        used_frac: 0.3,
        wan_bandwidth: wan,
        diurnal_amp: amp,
        ar_coeff: 0.5,
        noise_frac: 0.02,
        congestion_prob: 0.0,
        latency: 0.02,
        drd_time_ms: 5.0,
        dwr_time_ms: 6.0,
    };
    GridConfig {
        sites: vec![
            site("hot", 3.0e6, 0.9),
            site("flat-a", 1.2e6, 0.05),
            site("flat-b", 1.2e6, 0.05),
            site("flat-c", 1.2e6, 0.05),
        ],
        seed: 4242,
    }
}

#[test]
fn coalloc_beats_best_single_source_on_degrading_best_link() {
    let cfg = degrading_grid();
    let spec = WorkloadSpec { files: 2, ..Default::default() };
    let mut g = SimGrid::build(&cfg, &spec, 4, 32);
    g.warm(6); // history collected while "hot" really is hottest
    // Advance to the diurnal trough: the hot link now runs at 10% of
    // its mean while history still advertises it as the best source.
    g.topo.advance(21_600.0 - g.topo.now);
    g.publish_dynamics();

    let broker = g.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad(
        "hostname = \"client\"; reqdSpace = 0; requirement = other.AvgRDBandwidth > 0;",
    )
    .unwrap();
    let logical = g.files[0].clone();
    let size = 1.5e9; // a large file: ~90 blocks at 16 MiB
    let policy = CoallocPolicy {
        max_streams: 4,
        tick: 2.0,
        ..Default::default()
    };

    let sel = broker
        .select_coalloc(&logical, &request, size, &policy)
        .expect("coalloc selection");
    // History (from the warm phase) still ranks the degraded link #1.
    assert_eq!(sel.selection.site, "hot");
    assert_eq!(sel.plan.assignments.len(), 4, "all four replicas stripe");
    let hot = sel
        .plan
        .assignments
        .iter()
        .find(|a| a.source.site == "hot")
        .unwrap();
    assert!(
        sel.plan
            .assignments
            .iter()
            .all(|a| a.share <= hot.share + 1e-12),
        "the predicted-fastest source gets the largest stripe"
    );

    // Cost of the best single-source fetch, probed per site on clones
    // that will see the identical upcoming link behaviour.
    let best_single = (0..g.topo.len())
        .map(|s| {
            let mut probe = g.topo.clone_for_probe();
            probe.begin_transfer(s);
            let (d, _) = probe.transfer_from(s, size);
            d
        })
        .fold(f64::INFINITY, f64::min);

    let before: Vec<u64> = (0..g.topo.len())
        .map(|i| g.ftp.history(i).read().unwrap().rd.count)
        .collect();

    let out = coalloc::execute(&mut g.topo, &g.ftp, "client", &sel.plan, &policy)
        .expect("coalloc execution");

    // ≥3 replicas genuinely participated.
    let active_streams = out.streams.iter().filter(|s| s.blocks > 0).count();
    assert!(active_streams >= 3, "only {active_streams} streams moved bytes");
    assert!((out.bytes - size).abs() < 1.0);

    // The headline: striping beats even the *best* single source (not
    // just the broker's history-misled pick).
    assert!(
        out.duration < best_single,
        "coalloc {:.0}s !< best single {:.0}s",
        out.duration,
        best_single
    );

    // The degraded hot stream shed work to the steady peers.
    assert!(out.steals > 0, "expected rebalancing steals");
    let hot_stream = out.streams.iter().find(|s| s.site == "hot").unwrap();
    let flat_blocks: usize = out
        .streams
        .iter()
        .filter(|s| s.site != "hot")
        .map(|s| s.blocks)
        .sum();
    assert!(
        hot_stream.blocks < hot.blocks,
        "hot delivered {} of its {} planned blocks without shedding any",
        hot_stream.blocks,
        hot.blocks
    );
    assert!(flat_blocks > hot_stream.blocks);

    // Per-source instrumentation landed in the same HistoryStore the
    // GRIS providers read: counts grew by exactly the delivered blocks…
    for s in &out.streams {
        let h = g.ftp.history(s.site_index);
        let h = h.read().unwrap();
        assert_eq!(
            h.rd.count,
            before[s.site_index] + s.blocks as u64,
            "history count mismatch at {}",
            s.site
        );
        assert!(h.source("client").is_some());
    }
    // …and a fresh broker Search sees the new observations through the
    // live GRIS providers (rdHistory windows grew past the warm phase).
    g.publish_dynamics();
    let (cands, _) = broker.search(&logical, &request).unwrap();
    for c in &cands {
        assert!(
            c.history.len() > 6,
            "site {} publishes only {} observations after striping",
            c.site,
            c.history.len()
        );
    }
}

#[test]
fn single_stream_coalloc_degenerates_to_single_source() {
    // With max_streams = 1 the subsystem must behave like the paper's
    // plain Access phase: one source, no steals, same byte count.
    let cfg = degrading_grid();
    let spec = WorkloadSpec { files: 2, ..Default::default() };
    let mut g = SimGrid::build(&cfg, &spec, 4, 32);
    g.warm(4);

    let broker = g.broker(RankPolicy::ForecastBandwidth { engine: None });
    let request = parse_classad("requirement = TRUE;").unwrap();
    let logical = g.files[0].clone();
    let policy = CoallocPolicy { max_streams: 1, tick: 2.0, ..Default::default() };
    let sel = broker
        .select_coalloc(&logical, &request, 200e6, &policy)
        .expect("selection");
    assert_eq!(sel.plan.assignments.len(), 1);
    assert_eq!(sel.plan.assignments[0].source.site, sel.selection.site);

    let out = coalloc::execute(&mut g.topo, &g.ftp, "client", &sel.plan, &policy)
        .expect("execution");
    assert_eq!(out.steals, 0);
    assert_eq!(out.streams.len(), 1);
    assert!((out.bytes - 200e6).abs() < 1.0);
    assert!(out.duration > 0.0);
}
