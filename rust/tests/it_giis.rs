//! ISSUE 5 — hierarchical GIIS discovery: soft-state lifecycle on the
//! simulated clock, broad-from-summaries vs drill-down freshness, the
//! GIIS↔direct parity contract, and the scale sweep's acceptance
//! criteria (parity at zero staleness, drill-down query economy).

use std::sync::{Arc, Mutex, RwLock};

use globus_replica::broker::{Broker, HierDiscovery, LocalInfoService, RankPolicy};
use globus_replica::catalog::{PhysicalLocation, ReplicaCatalog};
use globus_replica::classad::parse_classad;
use globus_replica::directory::client::DirectoryClient;
use globus_replica::directory::entry::{format_f64, Dn, Entry};
use globus_replica::directory::server::DirectoryServer;
use globus_replica::directory::{Giis, Gris, HierarchicalDirectory};
use globus_replica::experiment::{run_scale, ScaleOptions, SimGrid};
use globus_replica::simnet::WorkloadSpec;

// ---------------------------------------------------------------- //
// Soft-state lifecycle on the sim clock
// ---------------------------------------------------------------- //

#[test]
fn registration_lifecycle_is_a_pure_function_of_simulated_time() {
    let mut g = Giis::with_ttl(100.0);
    let dn = Dn::parse("ou=mcs, o=anl, o=grid").unwrap();
    g.register("mcs", "a:1", dn.clone(), vec![]);
    // A whole "day" of simulated time passes in microseconds of real
    // time; expiry must track the former, never the latter.
    for (t, live) in [(50.0, true), (99.0, true), (101.0, false), (5000.0, false)] {
        let mut probe = Giis::with_ttl(100.0);
        probe.register("mcs", "a:1", dn.clone(), vec![]);
        probe.advance_to(t);
        assert_eq!(probe.lookup("mcs").is_some(), live, "t={t}");
    }
    // Refresh churn: expire → re-register → live again, restamped.
    g.advance_to(150.0);
    assert!(g.lookup("mcs").is_none());
    assert_eq!(g.sweep(), 1);
    g.register("mcs", "a:1", dn, vec![]);
    let r = g.lookup("mcs").unwrap();
    assert_eq!(r.registered_at(), 150.0);
    assert!(!r.expired(249.0));
    assert!(r.expired(251.0));
}

#[test]
fn tcp_registration_carries_ttl_and_ages_on_the_sim_clock() {
    let giis = Arc::new(Mutex::new(Giis::with_ttl(300.0)));
    let srv = DirectoryServer::spawn(giis.clone(), 0).unwrap();
    let mut c = DirectoryClient::connect(srv.addr()).unwrap();
    let base = Dn::parse("ou=mcs, o=anl, o=grid").unwrap();
    c.register_ttl("mcs", "10.0.0.1:9000", &base, vec![], Some(5.0))
        .unwrap();
    c.register("dsd", "10.0.0.2:9000", &base, vec![]).unwrap();
    assert_eq!(c.list().unwrap().len(), 2);
    // Advance the *server's* simulated clock past the short TTL.
    giis.lock().unwrap().advance_to(10.0);
    let live = c.list().unwrap();
    assert_eq!(live.len(), 1, "5 s TTL expired, default TTL survived");
    assert_eq!(live[0].first("site").unwrap(), "dsd");
    assert_eq!(live[0].f64("regAge"), Some(10.0));
}

// ---------------------------------------------------------------- //
// A two-site grid whose "fast" site turns slow after registration —
// the staleness scenario the hierarchy must expose and drill-down
// must correct.
// ---------------------------------------------------------------- //

struct TwoSiteGrid {
    direct: Broker,
    hier_dir: Arc<RwLock<HierarchicalDirectory>>,
    catalog: Arc<Mutex<ReplicaCatalog>>,
    info: Arc<LocalInfoService>,
    /// Live history of the "flaky" site (fast at registration time).
    flaky_hist: Arc<RwLock<Vec<f64>>>,
}

fn site_gris(name: &str, hist: Arc<RwLock<Vec<f64>>>) -> Arc<RwLock<Gris>> {
    let mut g = Gris::new("org", name);
    let base = g.base_dn().clone();
    let vol = base.child("gss", "vol0");
    let mut e = Entry::new(vol.clone());
    e.add("objectClass", "GridStorageServerVolume");
    e.put_f64("totalSpace", 1e12);
    e.put_f64("availableSpace", 1e11);
    g.add_entry(e);
    g.add_provider(
        &vol,
        Arc::new(move || {
            let h = hist.read().unwrap();
            vec![
                (
                    "rdHistory".into(),
                    h.iter().map(|v| format_f64(*v)).collect::<Vec<_>>().join(","),
                ),
                ("AvgRDBandwidth".into(), format_f64(h.iter().sum::<f64>() / h.len() as f64)),
            ]
        }),
    );
    Arc::new(RwLock::new(g))
}

fn two_site_grid() -> TwoSiteGrid {
    let mut catalog = ReplicaCatalog::new();
    catalog
        .create_logical("data.bin", globus_replica::util::units::Bytes(1e9), "sim")
        .unwrap();
    let flaky_hist = Arc::new(RwLock::new(vec![100e3, 102e3, 101e3]));
    let steady_hist = Arc::new(RwLock::new(vec![50e3, 51e3, 50e3]));
    let mut info = LocalInfoService::new();
    let mut dir = HierarchicalDirectory::new(f64::INFINITY);
    for (site, hist) in [("flaky", flaky_hist.clone()), ("steady", steady_hist)] {
        catalog
            .add_replica(
                "data.bin",
                PhysicalLocation { site: site.into(), url: format!("gsiftp://{site}/data.bin") },
            )
            .unwrap();
        let gris = site_gris(site, hist);
        dir.add_site(site, gris.clone());
        info.add(site, gris);
    }
    dir.refresh_all(); // snapshot while "flaky" is fast
    let catalog = Arc::new(Mutex::new(catalog));
    let info = Arc::new(info);
    let direct = Broker::new(
        catalog.clone(),
        info.clone(),
        RankPolicy::ForecastBandwidth { engine: None },
    );
    TwoSiteGrid {
        direct,
        hier_dir: Arc::new(RwLock::new(dir)),
        catalog,
        info,
        flaky_hist,
    }
}

impl TwoSiteGrid {
    fn hier_broker(&self, drill_down: usize) -> Broker {
        Broker::new(
            self.catalog.clone(),
            self.info.clone(),
            RankPolicy::ForecastBandwidth { engine: None },
        )
        .with_discovery(HierDiscovery {
            dir: self.hier_dir.clone(),
            drill_down,
            degrade: false,
        })
    }
}

fn request() -> globus_replica::classad::ClassAd {
    parse_classad("reqdSpace = 0; requirement = TRUE;").unwrap()
}

#[test]
fn broad_query_serves_summaries_only_and_staleness_misleads_it() {
    let g = two_site_grid();
    // The flaky site collapses *after* registration.
    *g.flaky_hist.write().unwrap() = vec![1e3, 1.1e3, 0.9e3];
    let fresh = g.direct.select("data.bin", &request()).unwrap();
    assert_eq!(fresh.site, "steady", "fresh data sees the collapse");
    // Summaries-only hierarchy still believes the registration-time
    // snapshot: the stale route picks yesterday's winner.
    let stale = g.hier_broker(0).select("data.bin", &request()).unwrap();
    assert_eq!(stale.site, "flaky", "stale soft state misdirects selection");
    assert_eq!(stale.trace.drill_downs, 0);
    assert_eq!(stale.trace.summary_sites, 2);
    // A soft-state refresh re-converges the two routes.
    g.hier_dir.write().unwrap().refresh_all();
    let refreshed = g.hier_broker(0).select("data.bin", &request()).unwrap();
    assert_eq!(refreshed.site, "steady");
}

#[test]
fn drill_down_fetches_fresh_detail_for_the_top_candidate() {
    let g = two_site_grid();
    *g.flaky_hist.write().unwrap() = vec![1e3, 1.1e3, 0.9e3];
    // Drill-down 1: the summary-ranked leader ("flaky", per the stale
    // snapshot) gets a fresh query, which reveals the collapse — so
    // selection lands on "steady" even though its data is stale.
    let sel = g.hier_broker(1).select("data.bin", &request()).unwrap();
    assert_eq!(sel.site, "steady", "one drill-down corrects the stale winner");
    assert_eq!(sel.trace.drill_downs, 1);
    assert_eq!(sel.trace.summary_sites, 1);
    let stats = g.hier_dir.read().unwrap().stats();
    assert_eq!(stats.drill_downs, 1);
    assert_eq!(stats.broad_queries, 1);
}

#[test]
fn parity_giis_routed_equals_direct_when_fresh() {
    // The acceptance contract, on a full SimGrid with live dynamic
    // providers (space/load/history/prediction feeds): with every
    // registration freshly pushed, GIIS-routed selection is
    // indistinguishable from direct-GRIS selection — same winner, same
    // scores, same ranking — at any drill-down depth.
    let cfg = globus_replica::config::GridConfig::generate(8, 77);
    let spec = WorkloadSpec { files: 6, ..Default::default() };
    let mut grid = SimGrid::build(&cfg, &spec, 4, 64);
    grid.warm(3);
    let dir = grid.hierarchy(f64::INFINITY); // snapshot at the current clock
    let req = request();
    for drill in [0usize, 2, 4] {
        let direct = grid.broker(RankPolicy::ForecastBandwidth { engine: None });
        let hier = grid.broker_hier(
            RankPolicy::ForecastBandwidth { engine: None },
            dir.clone(),
            drill,
        );
        for file in &grid.files {
            let a = direct.select(file, &req).unwrap();
            let b = hier.select(file, &req).unwrap();
            assert_eq!(a.site, b.site, "file {file}, drill {drill}");
            assert_eq!(a.score, b.score);
            assert_eq!(a.trace.ranking, b.trace.ranking);
        }
    }
}

#[test]
fn scale_sweep_meets_the_acceptance_criteria() {
    // ≥ 3 site-count points; at zero staleness the GIIS route matches
    // the always-fresh oracle exactly, and at every point its
    // drill-down query bill is strictly below the full fan-out's.
    let spec = WorkloadSpec { files: 6, mean_interarrival: 60.0, ..Default::default() };
    let opts = ScaleOptions { n_requests: 12, replicas_per_file: 4, drill_down: 2, ..Default::default() };
    let r = run_scale(&[16, 32, 64], &[0.0, 1e9], &spec, &opts, 9001);
    assert_eq!(r.points.len(), 6);
    for p in &r.points {
        assert!(
            p.drill_queries < p.full_fanout_queries,
            "{} sites @ refresh {}: drill {} !< full {}",
            p.sites,
            p.refresh_period,
            p.drill_queries,
            p.full_fanout_queries
        );
        if p.refresh_period == 0.0 {
            assert_eq!(p.degradation, 1.0, "{} sites: parity at zero staleness", p.sites);
            assert_eq!(p.stale.mean_time, p.fresh.mean_time);
        } else {
            // The stale column still completes every request (TTL ∞)
            // and reports a finite, comparable gap.
            assert_eq!(p.stale.requests, 12);
            assert!(p.degradation.is_finite() && p.degradation > 0.0);
        }
    }
}
