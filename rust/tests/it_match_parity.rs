//! Parity: all three evaluators — the per-pair path, the compiled
//! tree-walk ([`CompiledMatch`]) and the bytecode VM (ad mode *and*
//! dense-table mode) — must return *identical* results on the paper's
//! example ads (the same fixtures as `it_classad_paper.rs`), on
//! UNDEFINED/ERROR requirement outcomes, on cyclic definitions, and
//! under case-insensitive attribute lookup. The tree-walker is the
//! reference; rank equality is checked on the f64 bits.

use globus_replica::classad::{
    eval_in_match, parse_classad, rank_candidates, rank_of, symmetric_match, CandidateTable,
    ClassAd, CompiledMatch, Match, Value, VmScratch,
};

/// Verbatim from the paper, §4 (Figure-4 storage ad shape).
const STORAGE: &str = r#"
    hostname = "hugo.mcs.anl.gov";
    volume = "/dev/sandbox";
    availableSpace = 50G;
    MaxRDBandwidth = 75K/Sec;
    requirement = other.reqdSpace < 10G
        && other.reqdRDBandwidth < 75K/Sec;
"#;

/// Verbatim from the paper, §5.2.
const REQUEST: &str = r#"
    hostname = "comet.xyz.com";
    reqdSpace = 5G;
    reqdRDBandwidth = 50K/Sec;
    rank = other.availableSpace;
    requirement = other.availableSpace > 5G
        && other.MaxRDBandwidth > 50K/Sec;
"#;

/// The per-pair path, exactly as the pre-compiled broker ran it:
/// symmetric match per candidate, rank for survivors, sort best-first
/// with catalog-order tiebreak.
fn per_pair_rank(request: &ClassAd, candidates: &[ClassAd]) -> Vec<Match> {
    let mut out: Vec<Match> = candidates
        .iter()
        .enumerate()
        .filter(|(_, c)| symmetric_match(request, c))
        .map(|(index, c)| Match { index, rank: rank_of(request, c) })
        .collect();
    out.sort_by(|a, b| {
        b.rank
            .partial_cmp(&a.rank)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.index.cmp(&b.index))
    });
    out
}

fn assert_parity(request: &ClassAd, candidates: &[ClassAd]) {
    let compiled = CompiledMatch::compile(request);
    let mut vm = VmScratch::default();
    let mut table = CandidateTable::default();
    table.rebuild(compiled.program(), candidates.iter());
    for (i, c) in candidates.iter().enumerate() {
        assert_eq!(
            compiled.matches(c),
            symmetric_match(request, c),
            "match parity diverged on candidate {i}"
        );
        assert_eq!(
            compiled.rank(c),
            rank_of(request, c),
            "rank parity diverged on candidate {i}"
        );
        // Third evaluator: the bytecode VM, in both ad and table mode.
        assert_eq!(
            compiled.matches_vm(c, &mut vm),
            compiled.matches(c),
            "vm match diverged from tree-walk on candidate {i}"
        );
        assert_eq!(
            compiled.matches_vm_row(c, &table, i, &mut vm),
            compiled.matches(c),
            "vm table-mode match diverged from tree-walk on candidate {i}"
        );
        assert_eq!(
            compiled.rank_vm(c, &mut vm).to_bits(),
            compiled.rank(c).to_bits(),
            "vm rank bits diverged from tree-walk on candidate {i}"
        );
    }
    assert_eq!(compiled.rank_candidates(candidates), per_pair_rank(request, candidates));
    assert_eq!(rank_candidates(request, candidates), per_pair_rank(request, candidates));
    // The fused VM batch pass (what the broker's Match phase runs) must
    // reproduce the tree-walk pass exactly — flags and ranked order.
    let (flags, ms) = compiled.match_and_rank(candidates.iter());
    let (mut vflags, mut vms) = (Vec::new(), Vec::new());
    compiled.match_and_rank_vm_into(candidates.iter(), Some(&table), &mut vflags, &mut vms, &mut vm);
    assert_eq!(flags, vflags, "vm batch flags diverged");
    assert_eq!(ms, vms, "vm batch ranking diverged");
}

#[test]
fn paper_example_ads_full_parity() {
    let request = parse_classad(REQUEST).unwrap();
    let storage = parse_classad(STORAGE).unwrap();
    assert_parity(&request, &[storage.clone()]);
    // The compiled path reproduces the paper's numbers exactly.
    let compiled = CompiledMatch::compile(&request);
    assert!(compiled.matches(&storage));
    assert_eq!(compiled.rank(&storage), 50.0 * 1024f64.powi(3));
    // ... and the evaluated rank Value (not just the f64 view) agrees.
    assert_eq!(
        eval_in_match(&request, &storage, "rank"),
        Value::Quantity { base: 50.0 * 1024f64.powi(3), rate: false }
    );
}

#[test]
fn mixed_fleet_parity_with_infeasible_candidates() {
    let request = parse_classad(REQUEST).unwrap();
    let mk = |space: &str, bw: &str| {
        parse_classad(&format!("availableSpace = {space}; MaxRDBandwidth = {bw};")).unwrap()
    };
    let candidates = vec![
        mk("10G", "60K/Sec"),
        mk("3G", "60K/Sec"),   // infeasible: space
        mk("80G", "60K/Sec"),
        mk("60G", "40K/Sec"),  // infeasible: bandwidth
        mk("20G", "90K/Sec"),
        parse_classad("availableSpace = 20G; MaxRDBandwidth = 90K/Sec; id = 5;").unwrap(),
    ];
    assert_parity(&request, &candidates);
    // Equal ranks (20G twice) keep catalog order in both paths.
    let ranked = rank_candidates(&request, &candidates);
    assert_eq!(ranked.iter().map(|m| m.index).collect::<Vec<_>>(), vec![2, 4, 5, 0]);
}

#[test]
fn undefined_requirement_fails_both_paths() {
    // The candidate references an attribute the request never publishes:
    // its requirements evaluate UNDEFINED, which fails the match.
    let request = parse_classad("reqdSpace = 1G; requirement = TRUE;").unwrap();
    let candidate = parse_classad("requirement = other.nonexistent < 5;").unwrap();
    assert_eq!(eval_in_match(&candidate, &request, "requirement"), Value::Undefined);
    assert_parity(&request, &[candidate.clone()]);
    assert!(!CompiledMatch::compile(&request).matches(&candidate));
}

#[test]
fn error_requirement_fails_both_paths() {
    let request = parse_classad("requirement = 1 / 0;").unwrap();
    let candidate = parse_classad("availableSpace = 50G;").unwrap();
    assert_eq!(eval_in_match(&request, &candidate, "requirement"), Value::Error);
    assert_parity(&request, &[candidate.clone()]);
    assert!(!CompiledMatch::compile(&request).matches(&candidate));
}

#[test]
fn cyclic_definitions_error_in_both_paths() {
    // Self-cycle inside the request's own requirements.
    let request = parse_classad("requirement = requirement;").unwrap();
    let candidate = parse_classad("availableSpace = 50G;").unwrap();
    assert_eq!(eval_in_match(&request, &candidate, "requirement"), Value::Error);
    assert_parity(&request, &[candidate.clone()]);

    // Mutual cycle across the match: rank chases other.x -> other.y -> ...
    let request = parse_classad("x = other.y; rank = x; requirement = TRUE;").unwrap();
    let candidate = parse_classad("y = other.x;").unwrap();
    assert_eq!(eval_in_match(&request, &candidate, "x"), Value::Error);
    assert_parity(&request, &[candidate.clone()]);
    // ERROR rank collapses to 0.0 on both paths (Condor's rule).
    assert_eq!(CompiledMatch::compile(&request).rank(&candidate), 0.0);
    assert_eq!(rank_of(&request, &candidate), 0.0);

    // Attribute chains within budget still resolve identically.
    let request =
        parse_classad("a = b + 1; b = 2; rank = a; requirement = TRUE;").unwrap();
    assert_eq!(eval_in_match(&request, &candidate, "a"), Value::Int(3));
    assert_eq!(CompiledMatch::compile(&request).rank(&candidate), 3.0);
    assert_eq!(rank_of(&request, &candidate), 3.0);
}

#[test]
fn case_insensitive_lookup_everywhere() {
    // Ads spell attributes one way, expressions reference them in
    // another case, and the public lookup API accepts any casing.
    let request = parse_classad(
        r#"ReqdSpace = 5G;
           rank = OTHER.AVAILABLESPACE;
           requirement = other.availablespace > 1G;"#,
    )
    .unwrap();
    let candidate = parse_classad(
        r#"AvailableSpace = 50G;
           requirement = OTHER.reqdspace < 10G;"#,
    )
    .unwrap();
    assert!(request.contains("reqdspace"));
    assert!(request.contains("REQDSPACE"));
    assert_eq!(request.value("reqdSPACE").as_number(), Some(5.0 * 1024f64.powi(3)));
    assert_eq!(candidate.value("availablespace").as_number(), Some(50.0 * 1024f64.powi(3)));
    assert_parity(&request, &[candidate.clone()]);
    assert!(CompiledMatch::compile(&request).matches(&candidate));
    assert_eq!(
        CompiledMatch::compile(&request).rank(&candidate),
        50.0 * 1024f64.powi(3)
    );
}

#[test]
fn rankless_and_requirementless_ads_parity() {
    let request = parse_classad("reqdSpace = 1G;").unwrap(); // no reqs, no rank
    let candidates = vec![
        parse_classad("availableSpace = 50G;").unwrap(),
        parse_classad("requirement = other.reqdSpace < 10G;").unwrap(),
        parse_classad("requirement = other.reqdSpace > 10G;").unwrap(), // rejects
    ];
    assert_parity(&request, &candidates);
    let ranked = rank_candidates(&request, &candidates);
    // All ranks 0.0: catalog order, rejecting candidate dropped.
    assert_eq!(ranked.iter().map(|m| m.index).collect::<Vec<_>>(), vec![0, 1]);
}

#[test]
fn placement_ad_parity_across_policies() {
    // ISSUE 10: the replica manager's placement ads (what
    // `rank_destinations` compiles to pick replication targets) run on
    // the same VM path as the Match phase — pin tree-vs-VM agreement
    // for both ranking policies over a fleet that exercises the space
    // requirement from both sides.
    use globus_replica::broker::replication::{PlacementPolicy, ReplicaManager};

    let mk = |space: &str, wr: &str| {
        parse_classad(&format!("availableSpace = {space}; AvgWRBandwidth = {wr};")).unwrap()
    };
    let candidates = vec![
        mk("10G", "60K/Sec"),
        mk("500M", "900K/Sec"), // infeasible: too small for a 1G file
        mk("80G", "10K/Sec"),
        mk("80G", "10K/Sec"),   // tie: catalog order must hold
        parse_classad("AvgWRBandwidth = 900K/Sec;").unwrap(), // no space attr
    ];
    for policy in [PlacementPolicy::MostSpace, PlacementPolicy::FastestWrite] {
        let request = ReplicaManager::placement_ad(1024f64.powi(3), policy);
        assert_parity(&request, &candidates);
    }
    // The policies disagree on the winner — the rank attribute is live.
    let space = ReplicaManager::placement_ad(1024f64.powi(3), PlacementPolicy::MostSpace);
    let write = ReplicaManager::placement_ad(1024f64.powi(3), PlacementPolicy::FastestWrite);
    assert_eq!(rank_candidates(&space, &candidates)[0].index, 2);
    assert_eq!(rank_candidates(&write, &candidates)[0].index, 0);
}

#[test]
fn requirements_spelling_preference_parity() {
    // An ad with BOTH spellings must honour `requirements` (Condor's)
    // over `requirement` (the paper's) on both paths.
    let request = parse_classad(
        "requirements = other.availableSpace > 1G; requirement = FALSE; rank = 1;",
    )
    .unwrap();
    let candidate = parse_classad("availableSpace = 50G;").unwrap();
    assert!(symmetric_match(&request, &candidate));
    assert!(CompiledMatch::compile(&request).matches(&candidate));
    assert_parity(&request, &[candidate]);
}
