//! Integration: the paper's §4/§5.2 example ads through the public API
//! (experiment X1 in DESIGN.md).

use globus_replica::classad::{
    eval_in_match, parse_classad, rank_candidates, symmetric_match, Value,
};

/// Verbatim from the paper, §4.
const STORAGE: &str = r#"
    hostname = "hugo.mcs.anl.gov";
    volume = "/dev/sandbox";
    availableSpace = 50G;
    MaxRDBandwidth = 75K/Sec;
    requirement = other.reqdSpace < 10G
        && other.reqdRDBandwidth < 75K/Sec;
"#;

/// Verbatim from the paper, §5.2.
const REQUEST: &str = r#"
    hostname = "comet.xyz.com";
    reqdSpace = 5G;
    reqdRDBandwidth = 50K/Sec;
    rank = other.availableSpace;
    requirement = other.availableSpace >
        5G && other.MaxRDBandwidth >
        50K/Sec;
"#;

#[test]
fn paper_example_ads_match_and_rank() {
    let storage = parse_classad(STORAGE).unwrap();
    let request = parse_classad(REQUEST).unwrap();

    // "Two ClassAds match if the logical expressions contained in the
    // requirements attribute in both of them is satisfied."
    assert!(symmetric_match(&request, &storage));

    // "we rank the replica servers based on their available space"
    let rank = eval_in_match(&request, &storage, "rank");
    assert_eq!(rank.as_number(), Some(50.0 * 1024f64.powi(3)));
    // The ad's `50G` literal keeps its unit through evaluation.
    assert!(rank.strict_eq(&Value::Quantity {
        base: 50.0 * 1024f64.powi(3),
        rate: false
    }));
}

#[test]
fn policy_boundaries_from_section_4() {
    let storage = parse_classad(STORAGE).unwrap();
    // Storage accepts only requests needing < 10G and < 75K/Sec.
    let at_limit = parse_classad(
        "reqdSpace = 10G; reqdRDBandwidth = 50K/Sec; requirement = TRUE;",
    )
    .unwrap();
    assert!(!symmetric_match(&at_limit, &storage), "10G is not < 10G");
    let under = parse_classad(
        "reqdSpace = 9.9G; reqdRDBandwidth = 74K/Sec; requirement = TRUE;",
    )
    .unwrap();
    assert!(symmetric_match(&under, &storage));
    let too_fast = parse_classad(
        "reqdSpace = 1G; reqdRDBandwidth = 75K/Sec; requirement = TRUE;",
    )
    .unwrap();
    assert!(!symmetric_match(&too_fast, &storage), "75K is not < 75K");
}

#[test]
fn request_boundaries_from_section_5_2() {
    let request = parse_classad(REQUEST).unwrap();
    // > 5G and > 50K/Sec are strict.
    let exactly = parse_classad("availableSpace = 5G; MaxRDBandwidth = 50K/Sec;").unwrap();
    assert!(!symmetric_match(&request, &exactly));
    let above = parse_classad("availableSpace = 5.1G; MaxRDBandwidth = 51K/Sec;").unwrap();
    // The storage side has no requirements -> always willing; the
    // request side must still satisfy its own.
    assert!(symmetric_match(&request, &above));
}

#[test]
fn best_match_is_max_available_space() {
    let request = parse_classad(REQUEST).unwrap();
    let ads: Vec<_> = [
        ("10G", "60K/Sec"),
        ("80G", "60K/Sec"),
        ("3G", "90K/Sec"),   // infeasible: space
        ("90G", "40K/Sec"),  // infeasible: bandwidth
        ("20G", "90K/Sec"),
    ]
    .iter()
    .map(|(space, bw)| {
        parse_classad(&format!(
            "availableSpace = {space}; MaxRDBandwidth = {bw};"
        ))
        .unwrap()
    })
    .collect();
    let ranked = rank_candidates(&request, &ads);
    assert_eq!(ranked.len(), 3);
    assert_eq!(ranked[0].index, 1, "80G feasible replica must win");
    assert_eq!(ranked[1].index, 4);
    assert_eq!(ranked[2].index, 0);
}

#[test]
fn unparse_reparse_preserves_matching_semantics() {
    let storage = parse_classad(STORAGE).unwrap();
    let request = parse_classad(REQUEST).unwrap();
    let storage2 = parse_classad(&storage.to_string()).unwrap();
    let request2 = parse_classad(&request.to_string()).unwrap();
    assert!(symmetric_match(&request2, &storage2));
    assert_eq!(
        eval_in_match(&request2, &storage2, "rank"),
        eval_in_match(&request, &storage, "rank")
    );
}
