//! Open-loop runtime integration tests (ISSUE 4 acceptance).
//!
//! (a) **Parity**: the event kernel at concurrency 1 with the analytic
//!     Access primitive reproduces the legacy serial replay
//!     (`run_quality_trace`) bit-for-bit on identical seeds — the
//!     kernel generalizes the old semantics, it does not drift from
//!     them.
//! (b) **Contention**: two simultaneous clients fetching over one
//!     site's link each see reduced bandwidth versus running alone,
//!     and their flows overlap in time (asserted from the recorded
//!     start/finish instants).

use globus_replica::broker::selectors::SelectorKind;
use globus_replica::config::GridConfig;
use globus_replica::experiment::{run_quality_open, run_quality_trace, OpenLoopOptions};
use globus_replica::simnet::{Request, Workload, WorkloadSpec};

/// Deterministic single-rate links: durations depend only on sharing.
fn flat_cfg(n: usize, seed: u64) -> GridConfig {
    let mut cfg = GridConfig::generate(n, seed);
    for s in &mut cfg.sites {
        s.wan_bandwidth = 1e6;
        s.diurnal_amp = 0.0;
        s.noise_frac = 0.0;
        s.congestion_prob = 0.0;
        s.ar_coeff = 0.0;
        s.latency = 0.0;
        s.drd_time_ms = 0.0;
        s.disk_rate = 1e9;
    }
    cfg
}

#[test]
fn concurrency_1_open_loop_matches_serial_replay_exactly() {
    let cfg = GridConfig::generate(6, 1234);
    let spec = WorkloadSpec { files: 8, mean_interarrival: 120.0, ..Default::default() };
    let reqs = Workload::new(spec.clone(), cfg.seed).take(30);
    for kind in [SelectorKind::Forecast, SelectorKind::Random, SelectorKind::RoundRobin] {
        let serial = run_quality_trace(&cfg, &spec, &reqs, 3, 4, kind, None);
        let open = run_quality_open(
            &cfg,
            &spec,
            &reqs,
            3,
            4,
            kind,
            &OpenLoopOptions::serial(),
            None,
        );
        // Bit-for-bit: same clock arithmetic, same selection sequence,
        // same Access primitive, same aggregation.
        assert_eq!(serial.requests, open.quality.requests, "{kind:?}");
        assert_eq!(serial.mean_time, open.quality.mean_time, "{kind:?}");
        assert_eq!(serial.p95_time, open.quality.p95_time, "{kind:?}");
        assert_eq!(serial.mean_bandwidth, open.quality.mean_bandwidth, "{kind:?}");
        assert_eq!(serial.pct_optimal, open.quality.pct_optimal, "{kind:?}");
        assert_eq!(serial.mean_slowdown, open.quality.mean_slowdown, "{kind:?}");
        // The serial configuration never overlaps anything.
        assert_eq!(open.overlapped_admissions, 0, "{kind:?}");
        assert_eq!(open.skipped, 0, "{kind:?}");
    }
}

#[test]
fn two_simultaneous_clients_on_one_link_each_see_reduced_bandwidth() {
    // One site, so both requests must share the same link.
    let cfg = flat_cfg(1, 77);
    let spec = WorkloadSpec {
        files: 2,
        clients: 2,
        constrained_frac: 0.0,
        ..Default::default()
    };
    let solo_req = vec![Request { at: 0.0, client: 0, file: 0, min_bandwidth: 0.0 }];
    let pair = vec![
        Request { at: 0.0, client: 0, file: 0, min_bandwidth: 0.0 },
        Request { at: 0.1, client: 1, file: 0, min_bandwidth: 0.0 },
    ];
    let opts = OpenLoopOptions::open();
    let solo = run_quality_open(&cfg, &spec, &solo_req, 1, 1, SelectorKind::Forecast, &opts, None);
    let both = run_quality_open(&cfg, &spec, &pair, 1, 1, SelectorKind::Forecast, &opts, None);
    assert_eq!(solo.quality.requests, 1);
    assert_eq!(both.quality.requests, 2);

    // The two flows overlapped in time on the shared link...
    let a = both.per_request.iter().find(|t| t.request == 0).unwrap();
    let b = both.per_request.iter().find(|t| t.request == 1).unwrap();
    assert!(
        a.admitted_at < b.finished_at && b.admitted_at < a.finished_at,
        "flows must overlap: a=[{:.1},{:.1}] b=[{:.1},{:.1}]",
        a.admitted_at,
        a.finished_at,
        b.admitted_at,
        b.finished_at
    );
    assert!(both.overlapped_admissions > 0);
    assert!(both.peak_in_flight >= 2);

    // ...and each saw strictly less bandwidth than the transfer that
    // ran alone (same file, same bytes, same deterministic link).
    let solo_bw = solo.per_request[0].bandwidth;
    assert!(
        a.bandwidth < solo_bw && b.bandwidth < solo_bw,
        "contended bandwidth must drop: a={:.0} b={:.0} solo={:.0}",
        a.bandwidth,
        b.bandwidth,
        solo_bw
    );
    // Theory on a flat 1e6 B/s link: solo runs at share 1/2 = 0.5e6;
    // with both registered each runs at 1/3 ≈ 0.333e6 while
    // overlapped. Allow slack for the tails where one runs alone.
    assert!(
        a.bandwidth < solo_bw * 0.8,
        "contention too weak: {:.0} vs solo {:.0}",
        a.bandwidth,
        solo_bw
    );
}

#[test]
fn sparse_open_loop_equals_gated_run() {
    // When transfers never overlap, the pure open loop and the
    // concurrency-1 admission gate must produce identical flow-mode
    // results — the kernel invariance behind the parity claim.
    let cfg = flat_cfg(4, 55);
    let spec = WorkloadSpec {
        files: 4,
        clients: 2,
        constrained_frac: 0.0,
        ..Default::default()
    };
    let reqs = vec![
        Request { at: 0.0, client: 0, file: 0, min_bandwidth: 0.0 },
        Request { at: 5e5, client: 1, file: 1, min_bandwidth: 0.0 },
        Request { at: 1e6, client: 0, file: 2, min_bandwidth: 0.0 },
    ];
    let open = run_quality_open(
        &cfg,
        &spec,
        &reqs,
        2,
        1,
        SelectorKind::Forecast,
        &OpenLoopOptions::open(),
        None,
    );
    let gated = run_quality_open(
        &cfg,
        &spec,
        &reqs,
        2,
        1,
        SelectorKind::Forecast,
        &OpenLoopOptions { max_in_flight: 1, ..OpenLoopOptions::open() },
        None,
    );
    assert_eq!(open.quality.mean_time, gated.quality.mean_time);
    assert_eq!(open.quality.mean_bandwidth, gated.quality.mean_bandwidth);
    assert_eq!(open.makespan, gated.makespan);
    assert_eq!(open.overlapped_admissions, 0);
    assert_eq!(gated.overlapped_admissions, 0);
}
